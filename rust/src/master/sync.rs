//! Synchronous fastest-k SGD driver.
//!
//! Gradients travel through a [`CommChannel`]: each worker's response time
//! is its model-download delay **plus** compute delay **plus** the virtual
//! upload delay of its encoded gradient message, and the fastest-k
//! selection runs on that total — so a smaller encoding genuinely changes
//! which workers make the top k. Workers compute against the *broadcast
//! view* of the model (bitwise the master's model on the default dense
//! downlink, a residual-tracked reconstruction for compressed deltas),
//! and with a finite master-ingress capacity the k accepted uploads
//! serialize FIFO, pushing the round past the k-th arrival.
//! [`run_fastest_k`] uses the zero-cost dense channel and reproduces the
//! paper's compute-only timing exactly; [`run_fastest_k_comm`] takes an
//! explicit channel.

use crate::comm::CommChannel;
use crate::grad::GradBackend;
use crate::linalg::dot;
use crate::metrics::{Recorder, Sample};
use crate::policy::{IterationObs, KPolicy};
use crate::rng::Pcg64;
use crate::straggler::DelayModel;

/// Loop configuration.
#[derive(Debug, Clone)]
pub struct MasterConfig {
    /// Step size η.
    pub eta: f32,
    /// Heavy-ball momentum β (0 = plain SGD, the paper's setting).
    pub momentum: f32,
    /// Hard iteration cap J.
    pub max_iterations: u64,
    /// Stop once the virtual clock passes this (0 = no time budget).
    pub max_time: f64,
    /// Seed for the delay draws.
    pub seed: u64,
    /// Evaluate + record the error every this many iterations.
    pub record_stride: u64,
}

impl Default for MasterConfig {
    fn default() -> Self {
        Self {
            eta: 5e-4,
            momentum: 0.0,
            max_iterations: 10_000,
            max_time: 0.0,
            seed: 0,
            record_stride: 10,
        }
    }
}

/// Result of a fastest-k run.
pub struct FastestKRun {
    /// Error-vs-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Iterations completed.
    pub iterations: u64,
    /// Final virtual wall-clock.
    pub total_time: f64,
    /// (iteration, time, new_k) for every k change the policy made.
    pub k_changes: Vec<(u64, f64, usize)>,
    /// Encoded bytes of all accepted gradient messages.
    pub bytes_sent: u64,
    /// Total upload time of accepted messages (comm work, not critical
    /// path — the critical path is folded into `total_time`).
    pub comm_time: f64,
    /// Encoded bytes of all model downloads (each broadcast counts once
    /// per receiving worker).
    pub bytes_down: u64,
    /// Total download time charged (download work, mirroring `comm_time`).
    pub down_time: f64,
}

/// Select the indices of the k smallest delays and the k-th smallest value.
/// O(n) via quickselect; `idx` is scratch of len n.
pub fn fastest_k_select(
    delays: &[f64],
    k: usize,
    idx: &mut Vec<usize>,
) -> (f64, usize) {
    let n = delays.len();
    debug_assert!(k >= 1 && k <= n);
    idx.clear();
    idx.extend(0..n);
    if k < n {
        // total_cmp, not partial_cmp(..).unwrap(): a NaN delay (e.g. a
        // misconfigured trace or a poisoned link model) must sort as
        // slowest-of-all and lose the selection, never panic the run.
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            delays[a].total_cmp(&delays[b])
        });
        // After select_nth, positions 0..k hold the k fastest (unordered),
        // with the k-th order statistic exactly at position k-1.
        (delays[idx[k - 1]], k)
    } else {
        // k = n: wait for everyone; the iteration time is the max.
        let x_n = delays.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (x_n, k)
    }
}

/// Run synchronous fastest-k SGD from `w0` with the zero-cost dense
/// channel (gradients ship for free — the paper's timing model).
///
/// `eval_error` maps the current model to the reported error metric
/// (e.g. `F(w) − F*`); it is called every `record_stride` iterations.
pub fn run_fastest_k(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    policy: &mut dyn KPolicy,
    w0: &[f32],
    cfg: &MasterConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> FastestKRun {
    let n = backend.n_shards();
    let mut channel = CommChannel::dense(n);
    run_fastest_k_comm(backend, delays, policy, &mut channel, w0, cfg, eval_error)
}

/// Run synchronous fastest-k SGD from `w0`, shipping every accepted
/// gradient through `channel`.
///
/// Compression draws come from a dedicated rng stream, so the straggler
/// delay sequence is identical across schemes for a fixed seed — scheme
/// comparisons are paired. With [`CommChannel::dense`] this reproduces
/// [`run_fastest_k`] (and the pre-comm seed figures) bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn run_fastest_k_comm(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    policy: &mut dyn KPolicy,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &MasterConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> FastestKRun {
    let n = backend.n_shards();
    let d = backend.dim();
    assert_eq!(w0.len(), d, "w0 dimension mismatch");
    assert_eq!(
        channel.n(),
        n,
        "comm channel sized for {} workers, backend has {n}",
        channel.n()
    );

    let mut rng = Pcg64::seed_stream(cfg.seed, 0xFA57);
    let mut comm_rng = Pcg64::seed_stream(cfg.seed, 0xC044);
    // Dedicated stream for the downlink encoder; the default dense
    // broadcast draws nothing, so the delay stream is untouched.
    let mut bcast_rng = Pcg64::seed_stream(cfg.seed, 0xB04D);
    let bytes0 = channel.stats.bytes_sent;
    let comm_t0 = channel.stats.comm_time;
    let down0 = channel.stats.bytes_down;
    let down_t0 = channel.stats.down_time;
    let mut w = w0.to_vec();
    // The workers' model view: what the downlink broadcast reconstructs
    // each round (bitwise `w` on the default dense downlink).
    let mut w_view = w0.to_vec();
    let mut g = vec![0.0f32; d]; // ĝ_j
    let mut g_prev = vec![0.0f32; d]; // ĝ_{j−1}
    let mut partial = vec![0.0f32; d];
    let mut decoded = vec![0.0f32; d];
    let mut velocity: Option<Vec<f32>> = None;
    // Batched-backend scratch (allocated lazily, and only on the batched
    // aggregation path — shard-by-shard runs never pay the O(n·d) memory).
    let mut all_buf: Option<Vec<f32>> = None;
    let mut delay_buf = vec![0.0f64; n];
    let mut idx_buf: Vec<usize> = Vec::with_capacity(n);
    // Accepted-arrival scratch for the shared-ingress round clock.
    let mut arrival_buf: Vec<f64> = Vec::with_capacity(n);
    let ingress = *channel.ingress();

    let mut recorder =
        Recorder::with_stride(policy.name(), cfg.record_stride);
    let mut k_changes = Vec::new();
    let mut k = policy.initial_k().min(n).max(1);
    let mut t = 0.0f64;
    let mut j = 0u64;

    // Per-message upload pricing is data-independent, so the whole
    // round's comm delays are known before any gradient is computed. On a
    // zero-cost link the upload (and download) delay is exactly 0.0, and
    // `x + 0.0` is bitwise identity for the positive compute delays, so
    // no branch is needed to preserve the paper's compute-only
    // trajectories.
    let msg_bytes = channel.message_bytes(d);

    // Initial point.
    recorder.push_forced(Sample {
        iteration: 0,
        time: 0.0,
        k,
        error: eval_error(&w),
        ..Default::default()
    });

    while j < cfg.max_iterations && (cfg.max_time <= 0.0 || t < cfg.max_time) {
        backend.on_iteration(j);
        // (1) downlink: broadcast w_j; every worker computes against the
        // decoded view and is charged its download before compute starts.
        let down_bytes = channel.broadcast_model(&w, &mut w_view, &mut bcast_rng);
        // (2) response times (download + compute + upload) + fastest-k
        // selection. The free-downlink download delay is exactly 0.0, so
        // appending it preserves the uplink-only sums bitwise.
        for (i, slot) in delay_buf.iter_mut().enumerate() {
            *slot = delays.sample(j, i, &mut rng)
                + channel.link_upload_delay(i, msg_bytes)
                + channel.download_delay(i, down_bytes);
        }
        let (x_k, _) = fastest_k_select(&delay_buf, k, &mut idx_buf);
        // (2b) shared-ingress congestion: with finite master ingress the
        // k accepted uploads serialize FIFO, so the round ends at the
        // last accepted message's ingress finish, not the k-th arrival.
        // The unlimited default skips the sort and keeps x_k bitwise.
        let round_time = if ingress.is_unlimited() {
            x_k
        } else {
            arrival_buf.clear();
            arrival_buf.extend(idx_buf[..k].iter().map(|&i| delay_buf[i]));
            ingress.round_completion(&mut arrival_buf, msg_bytes)
        };
        t += round_time;

        // (3) aggregate the k fastest partial gradients — through the
        // batched path when the backend has one and k is past the
        // dispatch-cost crossover (~n/4, see GradBackend::all_grads),
        // else shard by shard. Each accepted gradient passes through the
        // channel (error feedback + compression + byte accounting).
        g.iter_mut().for_each(|v| *v = 0.0);
        let use_batched = backend.supports_all_grads() && 4 * k >= n;
        // The n*d scratch is allocated only when the batched path is
        // actually taken (hoisted behind the check — shard-by-shard runs
        // used to pay the full O(n·d) allocation for nothing).
        let mut batched = false;
        if use_batched {
            let buf = all_buf.get_or_insert_with(|| vec![0.0f32; n * d]);
            batched = backend.all_grads(&w_view, buf);
        }
        if batched {
            let buf =
                all_buf.as_ref().expect("batched scratch allocated above");
            for &worker in &idx_buf[..k] {
                let row = &buf[worker * d..(worker + 1) * d];
                channel.transmit(worker, row, &mut decoded, &mut comm_rng);
                for (gv, pv) in g.iter_mut().zip(&decoded) {
                    *gv += *pv;
                }
            }
        } else {
            for &worker in &idx_buf[..k] {
                backend.partial_grad(worker, &w_view, &mut partial);
                channel.transmit(worker, &partial, &mut decoded, &mut comm_rng);
                for (gv, pv) in g.iter_mut().zip(&decoded) {
                    *gv += *pv;
                }
            }
        }
        let inv_k = 1.0 / k as f32;
        for gv in g.iter_mut() {
            *gv *= inv_k;
        }

        // (4) SGD update (heavy-ball when momentum > 0; v reused across
        // iterations, allocated lazily only if needed).
        if cfg.momentum > 0.0 {
            let v = velocity.get_or_insert_with(|| vec![0.0f32; d]);
            for ((vv, wv), gv) in v.iter_mut().zip(w.iter_mut()).zip(&g) {
                *vv = cfg.momentum * *vv + *gv;
                *wv -= cfg.eta * *vv;
            }
        } else {
            for (wv, gv) in w.iter_mut().zip(&g) {
                *wv -= cfg.eta * *gv;
            }
        }

        // (5) policy feedback.
        let inner = if j == 0 { None } else { Some(dot(&g, &g_prev)) };
        let obs = IterationObs {
            iteration: j,
            time: t,
            k_used: k,
            grad_inner_prev: inner,
            grad_norm_sq: dot(&g, &g),
        };
        let k_next = policy.next_k(&obs).min(n).max(1);
        if k_next != k {
            k_changes.push((j, t, k_next));
            k = k_next;
        }
        std::mem::swap(&mut g, &mut g_prev);

        j += 1;
        if j % cfg.record_stride == 0 {
            recorder.push_forced(Sample {
                iteration: j,
                time: t,
                k,
                error: eval_error(&w),
                bytes: channel.stats.bytes_sent - bytes0,
                comm_time: channel.stats.comm_time - comm_t0,
                bytes_down: channel.stats.bytes_down - down0,
                down_time: channel.stats.down_time - down_t0,
            });
        }
    }

    // Always record the end state.
    if j % cfg.record_stride != 0 {
        recorder.push_forced(Sample {
            iteration: j,
            time: t,
            k,
            error: eval_error(&w),
            bytes: channel.stats.bytes_sent - bytes0,
            comm_time: channel.stats.comm_time - comm_t0,
            bytes_down: channel.stats.bytes_down - down0,
            down_time: channel.stats.down_time - down_t0,
        });
    }

    FastestKRun {
        recorder,
        w,
        iterations: j,
        total_time: t,
        k_changes,
        bytes_sent: channel.stats.bytes_sent - bytes0,
        comm_time: channel.stats.comm_time - comm_t0,
        bytes_down: channel.stats.bytes_down - down0,
        down_time: channel.stats.down_time - down_t0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    use crate::grad::NativeBackend;
    use crate::model::LinRegProblem;
    use crate::policy::FixedK;
    use crate::straggler::ExponentialDelays;

    fn small_setup() -> (NativeBackend, LinRegProblem) {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            3,
        );
        let problem = LinRegProblem::new(&ds);
        let backend = NativeBackend::new(Shards::partition(&ds, 10));
        (backend, problem)
    }

    #[test]
    fn fastest_k_select_finds_order_statistic() {
        let delays = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let mut idx = Vec::new();
        let (x2, _) = fastest_k_select(&delays, 2, &mut idx);
        assert_eq!(x2, 2.0);
        let mut fastest: Vec<usize> = idx[..2].to_vec();
        fastest.sort_unstable();
        assert_eq!(fastest, vec![1, 3]);
        // k = n degenerates to the max.
        let (x5, _) = fastest_k_select(&delays, 5, &mut idx);
        assert_eq!(x5, 5.0);
    }

    #[test]
    fn fastest_k_select_survives_nan_delays() {
        // Regression: a NaN delay used to panic the
        // partial_cmp(..).unwrap() inside select_nth_unstable_by. Under
        // total_cmp a NaN orders as slowest and simply loses.
        let delays = vec![5.0, f64::NAN, 1.0, f64::NAN, 3.0];
        let mut idx = Vec::new();
        let (x2, _) = fastest_k_select(&delays, 2, &mut idx);
        assert_eq!(x2, 3.0);
        let mut fastest: Vec<usize> = idx[..2].to_vec();
        fastest.sort_unstable();
        assert_eq!(fastest, vec![2, 4], "NaN workers must not be selected");
        // k = n must not panic either (f64::max ignores NaN).
        let (x5, _) = fastest_k_select(&delays, 5, &mut idx);
        assert_eq!(x5, 5.0);
    }

    #[test]
    fn error_decreases_under_training() {
        let (mut backend, problem) = small_setup();
        let delays = ExponentialDelays::new(1.0);
        let mut policy = FixedK::new(5);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 800,
            seed: 1,
            record_stride: 50,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run = run_fastest_k(
            &mut backend,
            &delays,
            &mut policy,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(
            last < first * 1e-2,
            "training failed to descend: {first} -> {last}"
        );
        assert_eq!(run.iterations, 800);
        assert!(run.total_time > 0.0);
    }

    #[test]
    fn time_budget_stops_the_run() {
        let (mut backend, problem) = small_setup();
        let delays = ExponentialDelays::new(1.0);
        let mut policy = FixedK::new(3);
        let cfg = MasterConfig {
            eta: 0.001,
            max_iterations: u64::MAX / 2,
            max_time: 25.0,
            seed: 2,
            record_stride: 10,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run = run_fastest_k(
            &mut backend,
            &delays,
            &mut policy,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        assert!(run.total_time >= 25.0);
        // One iteration past the budget at most.
        let mean_iter = run.total_time / run.iterations as f64;
        assert!(run.total_time < 25.0 + 20.0 * mean_iter);
    }

    #[test]
    fn identical_seeds_are_bitwise_reproducible() {
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 100,
            seed: 7,
            record_stride: 10,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run_once = || {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(4);
            run_fastest_k(
                &mut backend,
                &delays,
                &mut policy,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a.w, b.w);
        assert_eq!(a.total_time, b.total_time);
    }

    #[test]
    fn dense_comm_channel_reproduces_the_plain_run_bitwise() {
        use crate::comm::CommChannel;
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 120,
            seed: 13,
            record_stride: 20,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let plain = {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(4);
            run_fastest_k(
                &mut backend,
                &delays,
                &mut policy,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let comm = {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(4);
            let mut channel = CommChannel::dense(10);
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        assert_eq!(plain.w, comm.w);
        assert_eq!(plain.total_time, comm.total_time);
        assert_eq!(
            plain.recorder.samples().len(),
            comm.recorder.samples().len()
        );
        for (a, b) in
            plain.recorder.samples().iter().zip(comm.recorder.samples())
        {
            assert_eq!(a, b);
        }
        // Dense still meters bytes: 120 iters × k=4 × (16 + 40) bytes.
        assert_eq!(plain.bytes_sent, 120 * 4 * 56);
        assert_eq!(plain.comm_time, 0.0);
    }

    #[test]
    fn finite_bandwidth_slows_the_clock_and_is_metered() {
        use crate::comm::{CommChannel, Dense, LinkModel};
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.001,
            max_iterations: 100,
            seed: 21,
            record_stride: 50,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run_with_bw = |bandwidth: f64| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(5);
            let link = if bandwidth > 0.0 {
                LinkModel::uniform(10, bandwidth, 0.0)
            } else {
                LinkModel::zero_cost(10)
            };
            let mut channel =
                CommChannel::new(Box::new(Dense::new()), link, false);
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let free = run_with_bw(0.0);
        let slow = run_with_bw(56.0); // dense msg = 56 bytes -> +1.0/iter
        assert!(
            slow.total_time > free.total_time + 99.0,
            "upload delay must push every iteration out: {} vs {}",
            slow.total_time,
            free.total_time
        );
        assert!(slow.comm_time > 0.0);
        assert_eq!(slow.bytes_sent, free.bytes_sent);
        // The gradient math is identical — only the clock differs.
        assert_eq!(slow.w, free.w);
    }

    #[test]
    fn topk_with_feedback_trains_and_sends_fewer_bytes() {
        use crate::comm::{CommChannel, LinkModel, TopK};
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 2500,
            seed: 5,
            record_stride: 100,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let (mut backend, problem) = small_setup();
        let mut policy = FixedK::new(5);
        let mut channel = CommChannel::new(
            Box::new(TopK::new(0.3)),
            LinkModel::zero_cost(10),
            true,
        );
        let run = run_fastest_k_comm(
            &mut backend,
            &delays,
            &mut policy,
            &mut channel,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(
            last < first * 1e-2,
            "top-k + error feedback failed to descend: {first} -> {last}"
        );
        // 3 of 10 coords as (index, value) pairs: 16 + 3*8 = 40 < 56.
        assert_eq!(run.bytes_sent, 2500 * 5 * 40);
        // Cumulative bytes must be monotone in the recorded series.
        let samples = run.recorder.samples();
        for pair in samples.windows(2) {
            assert!(pair[1].bytes >= pair[0].bytes);
        }
    }

    #[test]
    fn explicit_free_bidirectional_channel_is_bitwise_the_plain_run() {
        // A channel with every new axis spelled out at its default
        // (dense free broadcast, unlimited ingress) must reproduce the
        // pre-downlink trajectories bit for bit.
        use crate::comm::{
            Broadcast, CommChannel, Dense, IngressModel, LinkModel,
        };
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 150,
            seed: 17,
            record_stride: 30,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run = |explicit: bool| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(4);
            let mut channel = if explicit {
                CommChannel::new(
                    Box::new(Dense::new()),
                    LinkModel::zero_cost(10),
                    false,
                )
                .with_broadcast(Broadcast::free(10))
                .with_ingress(IngressModel::unlimited())
            } else {
                CommChannel::dense(10)
            };
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let a = run(false);
        let b = run(true);
        assert_eq!(a.w, b.w);
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.recorder.samples(), b.recorder.samples());
        assert_eq!(a.bytes_down, b.bytes_down);
        // The free broadcast still meters downlink traffic: one dense
        // model per worker per iteration (d=10 -> 56 bytes).
        assert_eq!(a.bytes_down, 150 * 10 * 56);
        assert_eq!(a.down_time, 0.0);
    }

    #[test]
    fn finite_ingress_strictly_slows_rounds_but_keeps_the_math() {
        use crate::comm::{CommChannel, IngressModel};
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.001,
            max_iterations: 200,
            seed: 23,
            record_stride: 50,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run_with_ingress = |capacity: f64| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(5);
            let mut channel = CommChannel::dense(10)
                .with_ingress(IngressModel::new(capacity));
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let free = run_with_ingress(0.0); // unlimited
        // 56-byte dense messages at 56 B/t: 1.0 ingress service each, so
        // each k=5 round gains at least one service time.
        let congested = run_with_ingress(56.0);
        assert!(
            congested.total_time >= free.total_time + 200.0 - 1e-6,
            "ingress serialization must stretch every round: {} vs {}",
            congested.total_time,
            free.total_time
        );
        // Selection and gradient math are untouched — only the clock.
        assert_eq!(congested.w, free.w);
        assert_eq!(congested.bytes_sent, free.bytes_sent);
    }

    #[test]
    fn finite_downlink_bandwidth_slows_the_clock_only() {
        use crate::comm::{
            Broadcast, CommChannel, Dense, DownlinkMode, LinkModel,
        };
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.001,
            max_iterations: 100,
            seed: 29,
            record_stride: 50,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run_with_down_bw = |bw: f64| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(5);
            let link = if bw > 0.0 {
                LinkModel::uniform(10, bw, 0.0)
            } else {
                LinkModel::zero_cost(10)
            };
            let mut channel = CommChannel::dense(10).with_broadcast(
                Broadcast::new(Box::new(Dense::new()), link, DownlinkMode::Full),
            );
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let free = run_with_down_bw(0.0);
        let slow = run_with_down_bw(56.0); // 56-byte model -> +1.0/round
        assert!(
            slow.total_time > free.total_time + 99.0,
            "every worker's download must push every round out: {} vs {}",
            slow.total_time,
            free.total_time
        );
        assert!(slow.down_time > 0.0);
        assert_eq!(slow.bytes_down, free.bytes_down);
        assert_eq!(slow.w, free.w, "dense downlink must not change the math");
    }

    #[test]
    fn delta_downlink_trains_and_sends_fewer_downlink_bytes() {
        use crate::comm::{
            Broadcast, CommChannel, DownlinkMode, LinkModel, TopK,
        };
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 2000,
            seed: 31,
            record_stride: 100,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let (mut backend, problem) = small_setup();
        let mut policy = FixedK::new(5);
        let mut channel = CommChannel::dense(10).with_broadcast(
            Broadcast::new(
                Box::new(TopK::new(0.3)),
                LinkModel::zero_cost(10),
                DownlinkMode::Delta,
            ),
        );
        let run = run_fastest_k_comm(
            &mut backend,
            &delays,
            &mut policy,
            &mut channel,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(
            last < first * 1e-2,
            "delta downlink failed to descend: {first} -> {last}"
        );
        // Bootstrap round ships dense (56 B), the rest top-3-of-10 delta
        // messages (16 + 24 = 40 B), each received by all 10 workers.
        assert_eq!(run.bytes_down, 10 * (56 + 1999 * 40));
        // Residual-tracked view stays within a bounded lag of the model.
        assert!(channel.broadcast_residual_norm_sq().is_finite());
    }

    #[test]
    fn larger_k_takes_longer_per_iteration() {
        let delays = ExponentialDelays::new(1.0);
        let w0 = vec![0.0f32; 10];
        let time_for = |k: usize| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(k);
            let cfg = MasterConfig {
                eta: 0.001,
                max_iterations: 400,
                seed: 11,
                record_stride: 100,
                ..Default::default()
            };
            run_fastest_k(
                &mut backend,
                &delays,
                &mut policy,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
            .total_time
        };
        let t2 = time_for(2);
        let t8 = time_for(8);
        assert!(
            t8 > 2.0 * t2,
            "k=8 should be much slower than k=2: {t8} vs {t2}"
        );
    }
}
