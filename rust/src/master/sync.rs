//! Synchronous fastest-k SGD driver.
//!
//! Gradients travel through a [`CommChannel`]: each worker's response time
//! is its model-download delay **plus** compute delay **plus** the virtual
//! upload delay of its encoded gradient message, and the fastest-k
//! selection runs on that total — so a smaller encoding genuinely changes
//! which workers make the top k. Workers compute against the *broadcast
//! view* of the model (bitwise the master's model on the default dense
//! downlink, a residual-tracked reconstruction for compressed deltas),
//! and with a finite master-ingress capacity the k accepted uploads
//! serialize FIFO, pushing the round past the k-th arrival.
//! [`run_fastest_k`] uses the zero-cost dense channel and reproduces the
//! paper's compute-only timing exactly; [`run_fastest_k_comm`] takes an
//! explicit channel.
//!
//! Both are compatibility shims over the round engine: they build an
//! [`engine::EngineCore`](crate::engine::EngineCore) with the historical
//! sync rng streams and run the
//! [`engine::FastestKGather`](crate::engine::FastestKGather) discipline,
//! which preserves the pre-engine trajectories bit for bit (asserted by
//! `rust/tests/test_engine_equivalence.rs`).

use crate::comm::CommChannel;
use crate::engine::{
    EngineConfig, EngineCore, FastestKGather, RngStreams, RoundEngine,
};
use crate::grad::GradBackend;
use crate::metrics::Recorder;
use crate::policy::KPolicy;
use crate::straggler::DelayModel;
use crate::trace::{Discipline, Trace};

/// Loop configuration.
#[derive(Debug, Clone)]
pub struct MasterConfig {
    /// Step size η.
    pub eta: f32,
    /// Heavy-ball momentum β (0 = plain SGD, the paper's setting).
    pub momentum: f32,
    /// Hard iteration cap J.
    pub max_iterations: u64,
    /// Stop once the virtual clock passes this (0 = no time budget).
    pub max_time: f64,
    /// Seed for the delay draws.
    pub seed: u64,
    /// Evaluate + record the error every this many iterations.
    pub record_stride: u64,
    /// Intra-round worker budget (1 = serial, 0 = the machine). Pure
    /// wall-clock — trajectories are bitwise identical for every value.
    pub intra_jobs: usize,
}

impl Default for MasterConfig {
    fn default() -> Self {
        Self {
            eta: 5e-4,
            momentum: 0.0,
            max_iterations: 10_000,
            max_time: 0.0,
            seed: 0,
            record_stride: 10,
            intra_jobs: 1,
        }
    }
}

/// Result of a fastest-k run.
pub struct FastestKRun {
    /// Error-vs-time record.
    pub recorder: Recorder,
    /// Final model.
    pub w: Vec<f32>,
    /// Iterations completed.
    pub iterations: u64,
    /// Final virtual wall-clock.
    pub total_time: f64,
    /// (iteration, time, new_k) for every k change the policy made.
    pub k_changes: Vec<(u64, f64, usize)>,
    /// Encoded bytes of all accepted gradient messages.
    pub bytes_sent: u64,
    /// Total upload time of accepted messages (comm work, not critical
    /// path — the critical path is folded into `total_time`).
    pub comm_time: f64,
    /// Encoded bytes of all model downloads (each broadcast counts once
    /// per receiving worker).
    pub bytes_down: u64,
    /// Total download time charged (download work, mirroring `comm_time`).
    pub down_time: f64,
    /// Late (discarded) responses — 0 for the simulated disciplines,
    /// filled by the threaded cluster.
    pub late_responses: u64,
    /// Mean staleness of applied gradients — 0 for round disciplines.
    pub mean_staleness: f64,
    /// The binary event trace when tracing was enabled (see
    /// [`crate::trace`]), `None` otherwise.
    pub trace: Option<Trace>,
}

/// Select the indices of the k smallest delays and the k-th smallest value.
/// O(n) via quickselect; `idx` is scratch of len n.
pub fn fastest_k_select(
    delays: &[f64],
    k: usize,
    idx: &mut Vec<usize>,
) -> (f64, usize) {
    let n = delays.len();
    debug_assert!(k >= 1 && k <= n);
    idx.clear();
    idx.extend(0..n);
    if k < n {
        // total_cmp, not partial_cmp(..).unwrap(): a NaN delay (e.g. a
        // misconfigured trace or a poisoned link model) must sort as
        // slowest-of-all and lose the selection, never panic the run.
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            delays[a].total_cmp(&delays[b])
        });
        // After select_nth, positions 0..k hold the k fastest (unordered),
        // with the k-th order statistic exactly at position k-1.
        (delays[idx[k - 1]], k)
    } else {
        // k = n: wait for everyone; the iteration time is the max.
        let x_n = delays.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (x_n, k)
    }
}

/// Run synchronous fastest-k SGD from `w0` with the zero-cost dense
/// channel (gradients ship for free — the paper's timing model).
///
/// `eval_error` maps the current model to the reported error metric
/// (e.g. `F(w) − F*`); it is called every `record_stride` iterations.
pub fn run_fastest_k(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    policy: &mut dyn KPolicy,
    w0: &[f32],
    cfg: &MasterConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> FastestKRun {
    let n = backend.n_shards();
    let mut channel = CommChannel::dense(n);
    run_fastest_k_comm(backend, delays, policy, &mut channel, w0, cfg, eval_error)
}

/// Run synchronous fastest-k SGD from `w0`, shipping every accepted
/// gradient through `channel`.
///
/// Compression draws come from a dedicated rng stream, so the straggler
/// delay sequence is identical across schemes for a fixed seed — scheme
/// comparisons are paired. With [`CommChannel::dense`] this reproduces
/// [`run_fastest_k`] (and the pre-comm seed figures) bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn run_fastest_k_comm(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    policy: &mut dyn KPolicy,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &MasterConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> FastestKRun {
    run_fastest_k_comm_traced(
        backend, delays, policy, channel, w0, cfg, eval_error, false,
    )
}

/// [`run_fastest_k_comm`] with opt-in binary event tracing: when `trace`
/// is true the returned run carries a [`Trace`] of every engine event
/// (see [`crate::trace`]); the trajectory itself is bit-identical either
/// way.
#[allow(clippy::too_many_arguments)]
pub fn run_fastest_k_comm_traced(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    policy: &mut dyn KPolicy,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &MasterConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
    trace: bool,
) -> FastestKRun {
    let n = backend.n_shards();
    let d = backend.dim();
    assert_eq!(w0.len(), d, "w0 dimension mismatch");
    assert_eq!(
        channel.n(),
        n,
        "comm channel sized for {} workers, backend has {n}",
        channel.n()
    );

    let engine_cfg = EngineConfig {
        eta: cfg.eta,
        momentum: cfg.momentum,
        max_steps: cfg.max_iterations,
        max_time: cfg.max_time,
        seed: cfg.seed,
        record_stride: cfg.record_stride,
        intra_jobs: cfg.intra_jobs,
    };
    let mut core = EngineCore::new(
        policy.name(),
        channel,
        delays,
        eval_error,
        w0,
        engine_cfg,
        RngStreams::sync(cfg.seed),
    );
    if trace {
        core.enable_trace(Discipline::Sync);
    }
    let mut gather = FastestKGather::new(backend, policy);
    let run = RoundEngine::new(core).run(&mut gather);
    FastestKRun {
        recorder: run.recorder,
        w: run.w,
        iterations: run.steps,
        total_time: run.total_time,
        k_changes: run.k_changes,
        bytes_sent: run.bytes_sent,
        comm_time: run.comm_time,
        bytes_down: run.bytes_down,
        down_time: run.down_time,
        late_responses: run.late_responses,
        mean_staleness: run.mean_staleness,
        trace: run.trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Shards, SyntheticConfig, SyntheticDataset};
    use crate::grad::NativeBackend;
    use crate::model::LinRegProblem;
    use crate::policy::FixedK;
    use crate::straggler::ExponentialDelays;

    fn small_setup() -> (NativeBackend, LinRegProblem) {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m: 200, d: 10, ..Default::default() },
            3,
        );
        let problem = LinRegProblem::new(&ds);
        let backend = NativeBackend::new(Shards::partition(&ds, 10));
        (backend, problem)
    }

    #[test]
    fn fastest_k_select_finds_order_statistic() {
        let delays = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let mut idx = Vec::new();
        let (x2, _) = fastest_k_select(&delays, 2, &mut idx);
        assert_eq!(x2, 2.0);
        let mut fastest: Vec<usize> = idx[..2].to_vec();
        fastest.sort_unstable();
        assert_eq!(fastest, vec![1, 3]);
        // k = n degenerates to the max.
        let (x5, _) = fastest_k_select(&delays, 5, &mut idx);
        assert_eq!(x5, 5.0);
    }

    #[test]
    fn fastest_k_select_survives_nan_delays() {
        // Regression: a NaN delay used to panic the
        // partial_cmp(..).unwrap() inside select_nth_unstable_by. Under
        // total_cmp a NaN orders as slowest and simply loses.
        let delays = vec![5.0, f64::NAN, 1.0, f64::NAN, 3.0];
        let mut idx = Vec::new();
        let (x2, _) = fastest_k_select(&delays, 2, &mut idx);
        assert_eq!(x2, 3.0);
        let mut fastest: Vec<usize> = idx[..2].to_vec();
        fastest.sort_unstable();
        assert_eq!(fastest, vec![2, 4], "NaN workers must not be selected");
        // k = n must not panic either (f64::max ignores NaN).
        let (x5, _) = fastest_k_select(&delays, 5, &mut idx);
        assert_eq!(x5, 5.0);
    }

    #[test]
    fn error_decreases_under_training() {
        let (mut backend, problem) = small_setup();
        let delays = ExponentialDelays::new(1.0);
        let mut policy = FixedK::new(5);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 800,
            seed: 1,
            record_stride: 50,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run = run_fastest_k(
            &mut backend,
            &delays,
            &mut policy,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(
            last < first * 1e-2,
            "training failed to descend: {first} -> {last}"
        );
        assert_eq!(run.iterations, 800);
        assert!(run.total_time > 0.0);
    }

    #[test]
    fn time_budget_stops_the_run() {
        let (mut backend, problem) = small_setup();
        let delays = ExponentialDelays::new(1.0);
        let mut policy = FixedK::new(3);
        let cfg = MasterConfig {
            eta: 0.001,
            max_iterations: u64::MAX / 2,
            max_time: 25.0,
            seed: 2,
            record_stride: 10,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run = run_fastest_k(
            &mut backend,
            &delays,
            &mut policy,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        assert!(run.total_time >= 25.0);
        // One iteration past the budget at most.
        let mean_iter = run.total_time / run.iterations as f64;
        assert!(run.total_time < 25.0 + 20.0 * mean_iter);
    }

    #[test]
    fn identical_seeds_are_bitwise_reproducible() {
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 100,
            seed: 7,
            record_stride: 10,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run_once = || {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(4);
            run_fastest_k(
                &mut backend,
                &delays,
                &mut policy,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a.w, b.w);
        assert_eq!(a.total_time, b.total_time);
    }

    #[test]
    fn dense_comm_channel_reproduces_the_plain_run_bitwise() {
        use crate::comm::CommChannel;
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 120,
            seed: 13,
            record_stride: 20,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let plain = {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(4);
            run_fastest_k(
                &mut backend,
                &delays,
                &mut policy,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let comm = {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(4);
            let mut channel = CommChannel::dense(10);
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        assert_eq!(plain.w, comm.w);
        assert_eq!(plain.total_time, comm.total_time);
        assert_eq!(
            plain.recorder.samples().len(),
            comm.recorder.samples().len()
        );
        for (a, b) in
            plain.recorder.samples().iter().zip(comm.recorder.samples())
        {
            assert_eq!(a, b);
        }
        // Dense still meters bytes: 120 iters × k=4 × (16 + 40) bytes.
        assert_eq!(plain.bytes_sent, 120 * 4 * 56);
        assert_eq!(plain.comm_time, 0.0);
    }

    #[test]
    fn finite_bandwidth_slows_the_clock_and_is_metered() {
        use crate::comm::{CommChannel, Dense, LinkModel};
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.001,
            max_iterations: 100,
            seed: 21,
            record_stride: 50,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run_with_bw = |bandwidth: f64| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(5);
            let link = if bandwidth > 0.0 {
                LinkModel::uniform(10, bandwidth, 0.0)
            } else {
                LinkModel::zero_cost(10)
            };
            let mut channel =
                CommChannel::new(Box::new(Dense::new()), link, false);
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let free = run_with_bw(0.0);
        let slow = run_with_bw(56.0); // dense msg = 56 bytes -> +1.0/iter
        assert!(
            slow.total_time > free.total_time + 99.0,
            "upload delay must push every iteration out: {} vs {}",
            slow.total_time,
            free.total_time
        );
        assert!(slow.comm_time > 0.0);
        assert_eq!(slow.bytes_sent, free.bytes_sent);
        // The gradient math is identical — only the clock differs.
        assert_eq!(slow.w, free.w);
    }

    #[test]
    fn topk_with_feedback_trains_and_sends_fewer_bytes() {
        use crate::comm::{CommChannel, LinkModel, TopK};
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 2500,
            seed: 5,
            record_stride: 100,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let (mut backend, problem) = small_setup();
        let mut policy = FixedK::new(5);
        let mut channel = CommChannel::new(
            Box::new(TopK::new(0.3)),
            LinkModel::zero_cost(10),
            true,
        );
        let run = run_fastest_k_comm(
            &mut backend,
            &delays,
            &mut policy,
            &mut channel,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(
            last < first * 1e-2,
            "top-k + error feedback failed to descend: {first} -> {last}"
        );
        // 3 of 10 coords as (index, value) pairs: 16 + 3*8 = 40 < 56.
        assert_eq!(run.bytes_sent, 2500 * 5 * 40);
        // Cumulative bytes must be monotone in the recorded series.
        let samples = run.recorder.samples();
        for pair in samples.windows(2) {
            assert!(pair[1].bytes >= pair[0].bytes);
        }
    }

    #[test]
    fn explicit_free_bidirectional_channel_is_bitwise_the_plain_run() {
        // A channel with every new axis spelled out at its default
        // (dense free broadcast, unlimited ingress) must reproduce the
        // pre-downlink trajectories bit for bit.
        use crate::comm::{
            Broadcast, CommChannel, Dense, IngressModel, LinkModel,
        };
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 150,
            seed: 17,
            record_stride: 30,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run = |explicit: bool| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(4);
            let mut channel = if explicit {
                CommChannel::new(
                    Box::new(Dense::new()),
                    LinkModel::zero_cost(10),
                    false,
                )
                .with_broadcast(Broadcast::free(10))
                .with_ingress(IngressModel::unlimited())
            } else {
                CommChannel::dense(10)
            };
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let a = run(false);
        let b = run(true);
        assert_eq!(a.w, b.w);
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.recorder.samples(), b.recorder.samples());
        assert_eq!(a.bytes_down, b.bytes_down);
        // The free broadcast still meters downlink traffic: one dense
        // model per worker per iteration (d=10 -> 56 bytes).
        assert_eq!(a.bytes_down, 150 * 10 * 56);
        assert_eq!(a.down_time, 0.0);
    }

    #[test]
    fn finite_ingress_strictly_slows_rounds_but_keeps_the_math() {
        use crate::comm::{CommChannel, IngressModel};
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.001,
            max_iterations: 200,
            seed: 23,
            record_stride: 50,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run_with_ingress = |capacity: f64| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(5);
            let mut channel = CommChannel::dense(10)
                .with_ingress(IngressModel::new(capacity));
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let free = run_with_ingress(0.0); // unlimited
        // 56-byte dense messages at 56 B/t: 1.0 ingress service each, so
        // each k=5 round gains at least one service time.
        let congested = run_with_ingress(56.0);
        assert!(
            congested.total_time >= free.total_time + 200.0 - 1e-6,
            "ingress serialization must stretch every round: {} vs {}",
            congested.total_time,
            free.total_time
        );
        // Selection and gradient math are untouched — only the clock.
        assert_eq!(congested.w, free.w);
        assert_eq!(congested.bytes_sent, free.bytes_sent);
    }

    #[test]
    fn finite_downlink_bandwidth_slows_the_clock_only() {
        use crate::comm::{
            Broadcast, CommChannel, Dense, DownlinkMode, LinkModel,
        };
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.001,
            max_iterations: 100,
            seed: 29,
            record_stride: 50,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run_with_down_bw = |bw: f64| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(5);
            let link = if bw > 0.0 {
                LinkModel::uniform(10, bw, 0.0)
            } else {
                LinkModel::zero_cost(10)
            };
            let mut channel = CommChannel::dense(10).with_broadcast(
                Broadcast::new(Box::new(Dense::new()), link, DownlinkMode::Full),
            );
            run_fastest_k_comm(
                &mut backend,
                &delays,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let free = run_with_down_bw(0.0);
        let slow = run_with_down_bw(56.0); // 56-byte model -> +1.0/round
        assert!(
            slow.total_time > free.total_time + 99.0,
            "every worker's download must push every round out: {} vs {}",
            slow.total_time,
            free.total_time
        );
        assert!(slow.down_time > 0.0);
        assert_eq!(slow.bytes_down, free.bytes_down);
        assert_eq!(slow.w, free.w, "dense downlink must not change the math");
    }

    #[test]
    fn delta_downlink_trains_and_sends_fewer_downlink_bytes() {
        use crate::comm::{
            Broadcast, CommChannel, DownlinkMode, LinkModel, TopK,
        };
        let delays = ExponentialDelays::new(1.0);
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 2000,
            seed: 31,
            record_stride: 100,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let (mut backend, problem) = small_setup();
        let mut policy = FixedK::new(5);
        let mut channel = CommChannel::dense(10).with_broadcast(
            Broadcast::new(
                Box::new(TopK::new(0.3)),
                LinkModel::zero_cost(10),
                DownlinkMode::Delta,
            ),
        );
        let run = run_fastest_k_comm(
            &mut backend,
            &delays,
            &mut policy,
            &mut channel,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        let first = run.recorder.samples()[0].error;
        let last = run.recorder.last().unwrap().error;
        assert!(
            last < first * 1e-2,
            "delta downlink failed to descend: {first} -> {last}"
        );
        // Bootstrap round ships dense (56 B), the rest top-3-of-10 delta
        // messages (16 + 24 = 40 B), each received by all 10 workers.
        assert_eq!(run.bytes_down, 10 * (56 + 1999 * 40));
        // Residual-tracked view stays within a bounded lag of the model.
        assert!(channel.broadcast_residual_norm_sq().is_finite());
    }

    #[test]
    fn larger_k_takes_longer_per_iteration() {
        let delays = ExponentialDelays::new(1.0);
        let w0 = vec![0.0f32; 10];
        let time_for = |k: usize| {
            let (mut backend, problem) = small_setup();
            let mut policy = FixedK::new(k);
            let cfg = MasterConfig {
                eta: 0.001,
                max_iterations: 400,
                seed: 11,
                record_stride: 100,
                ..Default::default()
            };
            run_fastest_k(
                &mut backend,
                &delays,
                &mut policy,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
            .total_time
        };
        let t2 = time_for(2);
        let t8 = time_for(8);
        assert!(
            t8 > 2.0 * t2,
            "k=8 should be much slower than k=2: {t8} vs {t2}"
        );
    }
}
