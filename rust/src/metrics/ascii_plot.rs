//! Terminal figure rendering: log-y scatter of error-vs-time series, so
//! `adasgd fig2` prints a readable version of the paper's plots without a
//! plotting dependency.

use super::Recorder;

/// Multi-series ASCII plot with a log-scaled y axis.
pub struct AsciiPlot {
    width: usize,
    height: usize,
    title: String,
}

const GLYPHS: &[char] = &['*', '+', 'o', 'x', '#', '@', '%', '&'];

impl AsciiPlot {
    /// Plot canvas of `width x height` characters.
    pub fn new(title: impl Into<String>, width: usize, height: usize) -> Self {
        assert!(width >= 16 && height >= 4, "canvas too small");
        Self { width, height, title: title.into() }
    }

    /// Render the series (one glyph per run) into a string.
    pub fn render(&self, runs: &[&Recorder]) -> String {
        let mut t_max = 0.0f64;
        let mut e_min = f64::INFINITY;
        let mut e_max = f64::NEG_INFINITY;
        for r in runs {
            for s in r.samples() {
                t_max = t_max.max(s.time);
                if s.error > 0.0 {
                    e_min = e_min.min(s.error);
                    e_max = e_max.max(s.error);
                }
            }
        }
        if !e_min.is_finite() || t_max == 0.0 {
            return format!("{}\n(no positive data to plot)\n", self.title);
        }
        let (ly_min, ly_max) = (e_min.log10(), e_max.log10());
        let y_span = (ly_max - ly_min).max(1e-9);

        let mut grid = vec![vec![' '; self.width]; self.height];
        for (ri, r) in runs.iter().enumerate() {
            let glyph = GLYPHS[ri % GLYPHS.len()];
            for s in r.samples() {
                if s.error <= 0.0 {
                    continue;
                }
                let xf = (s.time / t_max).clamp(0.0, 1.0);
                let yf = ((s.error.log10() - ly_min) / y_span).clamp(0.0, 1.0);
                let x = (xf * (self.width - 1) as f64).round() as usize;
                let y = self.height - 1
                    - (yf * (self.height - 1) as f64).round() as usize;
                grid[y][x] = glyph;
            }
        }

        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        for (yi, row) in grid.iter().enumerate() {
            // y tick: log value at this row.
            let frac = 1.0 - yi as f64 / (self.height - 1) as f64;
            let val = 10f64.powf(ly_min + frac * y_span);
            out.push_str(&format!("{val:9.2e} |"));
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str(&format!(
            "{:>9}  +{}\n{:>9}   0{:>width$.0}\n",
            "",
            "-".repeat(self.width),
            "t:",
            t_max,
            width = self.width - 1
        ));
        for (ri, r) in runs.iter().enumerate() {
            out.push_str(&format!(
                "  {} {}\n",
                GLYPHS[ri % GLYPHS.len()],
                r.label
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Sample;

    #[test]
    fn renders_without_panic_and_contains_labels() {
        let mut a = Recorder::new("adaptive");
        let mut b = Recorder::new("fixed-k10");
        for j in 0..100u64 {
            let t = j as f64;
            a.push(Sample {
                iteration: j,
                time: t,
                k: 1,
                error: 100.0 * (-0.05 * t).exp() + 0.01,
                ..Default::default()
            });
            b.push(Sample {
                iteration: j,
                time: t,
                k: 10,
                error: 100.0 * (-0.02 * t).exp() + 0.1,
                ..Default::default()
            });
        }
        let plot = AsciiPlot::new("test", 60, 16).render(&[&a, &b]);
        assert!(plot.contains("adaptive"));
        assert!(plot.contains("fixed-k10"));
        assert!(plot.lines().count() > 16);
    }

    #[test]
    fn empty_series_is_graceful() {
        let r = Recorder::new("empty");
        let plot = AsciiPlot::new("t", 40, 8).render(&[&r]);
        assert!(plot.contains("no positive data"));
        // No runs at all behaves the same as runs with no samples.
        let plot = AsciiPlot::new("t", 40, 8).render(&[]);
        assert!(plot.contains("no positive data"));
    }

    #[test]
    fn single_point_renders_without_degenerate_axes() {
        // One sample: t_max > 0 and a zero log-y span — both axis
        // normalizations must stay finite instead of dividing by zero.
        let mut r = Recorder::new("one");
        r.push(Sample {
            iteration: 0,
            time: 2.0,
            k: 1,
            error: 0.5,
            ..Default::default()
        });
        let plot = AsciiPlot::new("t", 40, 8).render(&[&r]);
        assert!(plot.contains("one"), "{plot}");
        assert!(plot.contains('*'), "the point must land on the canvas:\n{plot}");
        assert!(!plot.contains("no positive data"));
    }

    #[test]
    fn single_point_at_time_zero_is_graceful() {
        // t_max == 0 has no x axis to scale; the renderer must fall back
        // to the no-data message rather than divide by zero.
        let mut r = Recorder::new("t0");
        r.push(Sample {
            iteration: 0,
            time: 0.0,
            k: 1,
            error: 1.0,
            ..Default::default()
        });
        let plot = AsciiPlot::new("t", 40, 8).render(&[&r]);
        assert!(plot.contains("no positive data"));
    }

    #[test]
    fn nan_and_nonpositive_errors_are_skipped_not_plotted() {
        // NaN errors fail both `> 0.0` (bounds) and the plot filter, so
        // a diverged run renders its finite prefix and drops the rest.
        let mut r = Recorder::new("diverged");
        r.push(Sample {
            iteration: 0,
            time: 1.0,
            k: 1,
            error: 4.0,
            ..Default::default()
        });
        r.push(Sample {
            iteration: 1,
            time: 2.0,
            k: 1,
            error: f64::NAN,
            ..Default::default()
        });
        r.push(Sample {
            iteration: 2,
            time: 3.0,
            k: 1,
            error: -1.0,
            ..Default::default()
        });
        let plot = AsciiPlot::new("t", 40, 8).render(&[&r]);
        assert!(plot.contains("diverged"));
        assert!(!plot.contains("NaN"), "{plot}");
        // An all-NaN record has no positive data at all.
        let mut nan_only = Recorder::new("nan");
        nan_only.push(Sample {
            iteration: 0,
            time: 1.0,
            k: 1,
            error: f64::NAN,
            ..Default::default()
        });
        let plot = AsciiPlot::new("t", 40, 8).render(&[&nan_only]);
        assert!(plot.contains("no positive data"));
    }
}
