//! CSV export of run records (no serde offline — hand-rolled writer).
//!
//! # Column schema (v4)
//!
//! One long-format table, one row per recorded [`Sample`] per run:
//!
//! | column       | type  | meaning                                           |
//! |--------------|-------|---------------------------------------------------|
//! | `label`      | str   | run label (policy / scheme name)                  |
//! | `iteration`  | u64   | iteration (sync) or update (async) index          |
//! | `time`       | f64   | virtual wall-clock after the iteration            |
//! | `k`          | usize | k in effect for the iteration (1 for async)       |
//! | `error`      | f64   | `F(w) − F*` (or raw loss), scientific notation    |
//! | `bytes`      | u64   | cumulative accepted gradient-message bytes (uplink) |
//! | `comm_time`  | f64   | cumulative upload time of accepted messages       |
//! | `bytes_down` | u64   | cumulative model-download bytes (sync broadcasts count once per receiving worker) |
//! | `down_time`  | f64   | cumulative download time charged                  |
//! | `late_responses` | u64 | whole-run count of discarded responses (wasted straggler work; 0 for async), repeated on every row of the run |
//! | `mean_staleness` | f64 | whole-run mean staleness of applied updates (0 for round disciplines), repeated on every row of the run |
//!
//! The schema only ever grows on the right: v2 files are a column-prefix
//! of v3 (which appended `bytes_down`/`down_time`), and v3 files are a
//! column-prefix of v4 (which appends the whole-run scalars
//! `late_responses`/`mean_staleness`). The first line of every file is a
//! `#`-prefixed comment naming the columns, followed by the
//! machine-readable header row — downstream plot scripts should match
//! columns by name from either line rather than hardcoding indices.
//! Labels must not contain commas.

use super::Recorder;
use std::io::Write;
use std::path::Path;

/// The column list, single source of truth for header + comment lines.
pub const CSV_COLUMNS: &str = "label,iteration,time,k,error,bytes,\
                               comm_time,bytes_down,down_time,\
                               late_responses,mean_staleness";

/// Whole-run scalar columns of schema v4, repeated on every row of the
/// run they describe (the long format has no per-run table to put them
/// in). [`write_csv`]/[`write_csv_with_header`] fill them with
/// [`RunScalars::default`] (all zero) for callers that only have
/// recorders.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RunScalars {
    /// Responses the gather discarded (stale generations plus fresh
    /// responses outside the fastest-k; 0 for async).
    pub late_responses: u64,
    /// Mean staleness of applied updates (0 for round disciplines).
    pub mean_staleness: f64,
}

/// CSV writing failures.
#[derive(Debug)]
pub enum CsvError {
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for CsvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsvError::Io(e) => write!(f, "csv io: {e}"),
        }
    }
}

impl std::error::Error for CsvError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CsvError::Io(e) => Some(e),
        }
    }
}

impl From<std::io::Error> for CsvError {
    fn from(e: std::io::Error) -> Self {
        CsvError::Io(e)
    }
}

/// Write one or more run records into a single long-format CSV (see the
/// module docs for the column schema). The v4 scalar columns are zero;
/// use [`write_csv_with_scalars`] when the run statistics are at hand.
pub fn write_csv(path: &Path, runs: &[&Recorder]) -> Result<(), CsvError> {
    write_csv_with_header(path, runs, &[])
}

/// [`write_csv`] with extra run-header comment lines: each `meta` entry
/// becomes one `# `-prefixed line between the version comment and the
/// column header (e.g. `coding: scheme=frc r=2`, so a results file
/// records *what* produced it, not just the series).
pub fn write_csv_with_header(
    path: &Path,
    runs: &[&Recorder],
    meta: &[String],
) -> Result<(), CsvError> {
    let paired: Vec<(&Recorder, RunScalars)> =
        runs.iter().map(|r| (*r, RunScalars::default())).collect();
    write_csv_with_scalars(path, &paired, meta)
}

/// The full v4 writer: each run carries its whole-run scalar columns
/// ([`RunScalars`]), repeated on every row of that run.
pub fn write_csv_with_scalars(
    path: &Path,
    runs: &[(&Recorder, RunScalars)],
    meta: &[String],
) -> Result<(), CsvError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# adasgd run series v4; columns: {CSV_COLUMNS}")?;
    for line in meta {
        writeln!(f, "# {line}")?;
    }
    writeln!(f, "{CSV_COLUMNS}")?;
    for (run, scalars) in runs {
        for s in run.samples() {
            writeln!(
                f,
                "{},{},{:.6},{},{:.9e},{},{:.6},{},{:.6},{},{:.6}",
                run.label,
                s.iteration,
                s.time,
                s.k,
                s.error,
                s.bytes,
                s.comm_time,
                s.bytes_down,
                s.down_time,
                scalars.late_responses,
                scalars.mean_staleness
            )?;
        }
    }
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Sample;

    #[test]
    fn round_trip_via_fs() {
        let mut r = Recorder::new("runA");
        r.push(Sample {
            iteration: 0,
            time: 0.5,
            k: 2,
            error: 3.25,
            bytes: 416,
            comm_time: 1.25,
            bytes_down: 832,
            down_time: 0.5,
        });
        let dir = std::env::temp_dir().join("adasgd_csv_test");
        let path = dir.join("out.csv");
        write_csv(&path, &[&r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let comment = lines.next().unwrap();
        assert!(comment.starts_with('#'), "{comment}");
        assert!(comment.contains(CSV_COLUMNS));
        assert_eq!(lines.next().unwrap(), CSV_COLUMNS);
        let row = lines.next().unwrap();
        assert!(row.starts_with("runA,0,0.5"), "{row}");
        assert!(row.contains(",416,"), "{row}");
        assert!(row.contains(",832,"), "{row}");
        // Scalar-less writers zero-fill the v4 columns.
        assert!(row.ends_with(",0,0.000000"), "{row}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scalars_repeat_on_every_row_of_their_run() {
        let mut a = Recorder::new("runA");
        a.push(Sample { iteration: 0, ..Default::default() });
        a.push(Sample { iteration: 1, ..Default::default() });
        let mut b = Recorder::new("runB");
        b.push(Sample { iteration: 0, ..Default::default() });
        let dir = std::env::temp_dir().join("adasgd_csv_scalars_test");
        let path = dir.join("out.csv");
        write_csv_with_scalars(
            &path,
            &[
                (&a, RunScalars { late_responses: 7, mean_staleness: 2.5 }),
                (&b, RunScalars::default()),
            ],
            &[],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let rows: Vec<&str> =
            text.lines().filter(|l| l.starts_with("run")).collect();
        assert_eq!(rows.len(), 3);
        assert!(rows[0].ends_with(",7,2.500000"), "{}", rows[0]);
        assert!(rows[1].ends_with(",7,2.500000"), "{}", rows[1]);
        assert!(rows[2].ends_with(",0,0.000000"), "{}", rows[2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn meta_lines_land_between_version_comment_and_header() {
        let mut r = Recorder::new("runB");
        r.push(Sample { iteration: 0, ..Default::default() });
        let dir = std::env::temp_dir().join("adasgd_csv_meta_test");
        let path = dir.join("out.csv");
        write_csv_with_header(
            &path,
            &[&r],
            &["coding: scheme=frc r=2".to_string()],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].starts_with("# adasgd run series v4"));
        assert_eq!(lines[1], "# coding: scheme=frc r=2");
        assert_eq!(lines[2], CSV_COLUMNS);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn header_and_comment_share_the_column_list() {
        // Guards against the comment line drifting from the real header.
        assert_eq!(CSV_COLUMNS.split(',').count(), 11);
        assert!(CSV_COLUMNS.ends_with("late_responses,mean_staleness"));
        // Older files must remain a column-prefix of newer ones: v2 of
        // v3, v3 of v4.
        assert!(CSV_COLUMNS
            .starts_with("label,iteration,time,k,error,bytes,comm_time"));
        assert!(CSV_COLUMNS.starts_with(
            "label,iteration,time,k,error,bytes,comm_time,bytes_down,\
             down_time"
        ));
    }
}
