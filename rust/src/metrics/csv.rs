//! CSV export of run records (no serde offline — hand-rolled writer).

use super::Recorder;
use std::io::Write;
use std::path::Path;

/// CSV writing failures.
#[derive(Debug, thiserror::Error)]
pub enum CsvError {
    /// Underlying I/O failure.
    #[error("csv io: {0}")]
    Io(#[from] std::io::Error),
}

/// Write one or more run records into a single long-format CSV:
/// `label,iteration,time,k,error`.
pub fn write_csv(path: &Path, runs: &[&Recorder]) -> Result<(), CsvError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "label,iteration,time,k,error")?;
    for run in runs {
        for s in run.samples() {
            writeln!(
                f,
                "{},{},{:.6},{},{:.9e}",
                run.label, s.iteration, s.time, s.k, s.error
            )?;
        }
    }
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Sample;

    #[test]
    fn round_trip_via_fs() {
        let mut r = Recorder::new("runA");
        r.push(Sample { iteration: 0, time: 0.5, k: 2, error: 3.25 });
        let dir = std::env::temp_dir().join("adasgd_csv_test");
        let path = dir.join("out.csv");
        write_csv(&path, &[&r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), "label,iteration,time,k,error");
        let row = lines.next().unwrap();
        assert!(row.starts_with("runA,0,0.5"), "{row}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
