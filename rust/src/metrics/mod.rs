//! Metrics: time-series recording, CSV export, terminal plots, summaries.

mod ascii_plot;
mod csv;
mod recorder;

pub use ascii_plot::AsciiPlot;
pub use csv::{
    write_csv, write_csv_with_header, write_csv_with_scalars, CsvError,
    RunScalars, CSV_COLUMNS,
};
pub use recorder::{Recorder, Sample};
