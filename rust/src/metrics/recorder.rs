//! Error-vs-wall-clock time series of a training run.

/// One recorded point of a run.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Sample {
    /// Iteration index j.
    pub iteration: u64,
    /// Wall-clock time after the iteration.
    pub time: f64,
    /// k used in the iteration.
    pub k: usize,
    /// Error metric F(w_j) − F* (or raw loss for workloads without F*).
    pub error: f64,
    /// Cumulative gradient-message bytes accepted by the master so far
    /// (0 for runs that predate / bypass the comm channel).
    pub bytes: u64,
    /// Cumulative upload time of accepted messages so far (total comm
    /// work, not critical path — see `comm::CommStats`).
    pub comm_time: f64,
    /// Cumulative model-download bytes so far (a sync broadcast counts
    /// once per receiving worker; 0 for pre-downlink runs).
    pub bytes_down: u64,
    /// Cumulative download time charged so far (total download work,
    /// mirroring `comm_time`).
    pub down_time: f64,
}

/// Growable run record with optional sub-sampling.
#[derive(Debug, Clone)]
pub struct Recorder {
    /// Run label (policy name etc.).
    pub label: String,
    samples: Vec<Sample>,
    /// Record every `every`-th iteration (1 = all).
    every: u64,
}

impl Recorder {
    /// Record every iteration.
    pub fn new(label: impl Into<String>) -> Self {
        Self::with_stride(label, 1)
    }

    /// Record every `every`-th iteration (the final sample of a run should
    /// be pushed with [`Recorder::push_forced`]).
    pub fn with_stride(label: impl Into<String>, every: u64) -> Self {
        assert!(every >= 1, "stride must be >= 1");
        Self { label: label.into(), samples: Vec::new(), every }
    }

    /// Maybe record (honours the stride).
    pub fn push(&mut self, s: Sample) {
        if s.iteration % self.every == 0 {
            self.samples.push(s);
        }
    }

    /// Record unconditionally.
    pub fn push_forced(&mut self, s: Sample) {
        self.samples.push(s);
    }

    /// All recorded samples.
    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Last recorded sample.
    pub fn last(&self) -> Option<&Sample> {
        self.samples.last()
    }

    /// First time at which the error drops to `target` or below
    /// (the "time-to-error" metric used to compare Fig. 2 curves).
    pub fn time_to_error(&self, target: f64) -> Option<f64> {
        self.samples.iter().find(|s| s.error <= target).map(|s| s.time)
    }

    /// Minimum error seen. Total order (`total_cmp`), so a NaN error
    /// sample — a diverged async run — ranks above every finite value
    /// and `+inf` instead of panicking post-run analysis; an all-NaN
    /// record reports NaN.
    pub fn min_error(&self) -> Option<f64> {
        self.samples.iter().map(|s| s.error).min_by(|a, b| a.total_cmp(b))
    }

    /// Error of the last sample at or before time `t` (step interpolation).
    pub fn error_at(&self, t: f64) -> Option<f64> {
        self.samples
            .iter()
            .take_while(|s| s.time <= t)
            .last()
            .map(|s| s.error)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(it: u64, time: f64, error: f64) -> Sample {
        Sample { iteration: it, time, k: 1, error, ..Default::default() }
    }

    #[test]
    fn stride_subsamples() {
        let mut r = Recorder::with_stride("x", 10);
        for j in 0..100 {
            r.push(sample(j, j as f64, 1.0));
        }
        assert_eq!(r.samples().len(), 10);
        r.push_forced(sample(99, 99.0, 0.5));
        assert_eq!(r.samples().len(), 11);
    }

    #[test]
    #[should_panic(expected = "stride must be >= 1")]
    fn stride_zero_is_rejected() {
        // A zero stride would make `iteration % every` divide by zero on
        // the first push; construction refuses it up front.
        Recorder::with_stride("x", 0);
    }

    #[test]
    fn stride_one_records_every_iteration() {
        let mut r = Recorder::with_stride("x", 1);
        for j in 0..7 {
            r.push(sample(j, j as f64, 1.0));
        }
        assert_eq!(r.samples().len(), 7);
        // `new` is the stride-1 recorder.
        let mut r2 = Recorder::new("y");
        for j in 0..7 {
            r2.push(sample(j, j as f64, 1.0));
        }
        assert_eq!(r2.samples().len(), r.samples().len());
    }

    #[test]
    fn final_step_off_stride_needs_push_forced() {
        // The engine contract: strided runs force-push their last step,
        // because an off-stride final iteration would otherwise vanish.
        let mut r = Recorder::with_stride("x", 10);
        for j in 0..=99 {
            r.push(sample(j, j as f64, 1.0));
        }
        // 0, 10, ..., 90 recorded; 99 dropped by the stride.
        assert_eq!(r.samples().len(), 10);
        assert_eq!(r.last().unwrap().iteration, 90);
        r.push_forced(sample(99, 99.0, 0.5));
        assert_eq!(r.last().unwrap().iteration, 99);
        // An on-stride final step force-pushed twice duplicates — the
        // engine's record_final only fires when the loop ends, exactly
        // once, so the recorder itself does not dedup.
        let mut r2 = Recorder::with_stride("y", 10);
        r2.push(sample(100, 100.0, 1.0));
        r2.push_forced(sample(100, 100.0, 1.0));
        assert_eq!(r2.samples().len(), 2);
    }

    #[test]
    fn time_to_error_finds_first_crossing() {
        let mut r = Recorder::new("x");
        r.push(sample(0, 0.0, 10.0));
        r.push(sample(1, 1.0, 5.0));
        r.push(sample(2, 2.0, 1.0));
        r.push(sample(3, 3.0, 2.0)); // bounces back up
        assert_eq!(r.time_to_error(5.0), Some(1.0));
        assert_eq!(r.time_to_error(1.5), Some(2.0));
        assert_eq!(r.time_to_error(0.1), None);
        assert_eq!(r.min_error(), Some(1.0));
    }

    #[test]
    fn min_error_survives_nan_samples() {
        // Regression: a diverged run's NaN error used to panic the
        // `partial_cmp(..).unwrap()` inside min_by mid-analysis.
        let mut r = Recorder::new("diverged");
        r.push(sample(0, 0.0, 10.0));
        r.push(sample(1, 1.0, f64::NAN));
        r.push(sample(2, 2.0, 3.0));
        r.push(sample(3, 3.0, f64::INFINITY));
        assert_eq!(r.min_error(), Some(3.0));
        // time_to_error must not treat NaN as a crossing either.
        assert_eq!(r.time_to_error(5.0), Some(2.0));
        // An all-NaN record reports NaN instead of aborting.
        let mut all_nan = Recorder::new("nan");
        all_nan.push(sample(0, 0.0, f64::NAN));
        assert!(all_nan.min_error().unwrap().is_nan());
    }

    #[test]
    fn error_at_steps() {
        let mut r = Recorder::new("x");
        r.push(sample(0, 0.0, 10.0));
        r.push(sample(1, 2.0, 5.0));
        assert_eq!(r.error_at(1.0), Some(10.0));
        assert_eq!(r.error_at(2.0), Some(5.0));
        assert_eq!(r.error_at(-1.0), None);
    }
}
