//! Model state and loss evaluation for the linear-regression workload.
//!
//! `F(w) = ||X w − y||² / (2m)`; the exact optimum `w*` (and hence `F*`)
//! comes from the normal equations via the Cholesky substrate, so every
//! experiment reports the paper's metric `F(w_t) − F*`.

use crate::data::SyntheticDataset;
use crate::linalg::{cholesky_solve_dense_f64, dot, gemv, gemv_t, Matrix};

/// Linear-regression problem with cached optimum.
#[derive(Debug, Clone)]
pub struct LinRegProblem {
    /// Full feature matrix X (m×d).
    pub x: Matrix,
    /// Full labels y (m).
    pub y: Vec<f32>,
    /// Exact minimizer w* of F (kept in f64: the error metric needs it).
    pub w_star_f64: Vec<f64>,
    /// `w*` narrowed to f32 (for f32 pipelines).
    pub w_star: Vec<f32>,
    /// Minimal loss F* = F(w*), f64.
    pub f_star: f64,
}

impl LinRegProblem {
    /// Build from a synthetic dataset, solving the normal equations once.
    pub fn new(ds: &SyntheticDataset) -> Self {
        let d = ds.d();
        let m = ds.m();
        // XᵀX (d×d) and Xᵀy (d) in f64 (entries reach ~m·10² ≈ 2·10⁵;
        // f32 gemm would lose the digits the floor measurement needs).
        let mut xtx64 = vec![0.0f64; d * d];
        let mut xty64 = vec![0.0f64; d];
        for i in 0..m {
            let row = ds.x.row(i);
            let yi = ds.y[i] as f64;
            for a in 0..d {
                let xa = row[a] as f64;
                xty64[a] += xa * yi;
                for b in a..d {
                    xtx64[a * d + b] += xa * row[b] as f64;
                }
            }
        }
        // Mirror the upper triangle; the whole solve stays in f64.
        for a in 0..d {
            for b in a..d {
                xtx64[b * d + a] = xtx64[a * d + b];
            }
        }
        let w_star_f64 = cholesky_solve_dense_f64(&xtx64, d, &xty64)
            .expect("X^T X must be SPD for the paper's data model");
        let w_star: Vec<f32> = w_star_f64.iter().map(|&v| v as f32).collect();
        let f_star = loss_f64w(&ds.x, &ds.y, &w_star_f64);
        Self { x: ds.x.clone(), y: ds.y.clone(), w_star_f64, w_star, f_star }
    }

    /// `F(w)`.
    pub fn loss(&self, w: &[f32]) -> f64 {
        loss(&self.x, &self.y, w)
    }

    /// The paper's error metric `F(w) − F*` (clamped at 0 against f32
    /// round-off; a non-finite loss — a diverged run — reports +∞ rather
    /// than being silently clamped).
    pub fn error(&self, w: &[f32]) -> f64 {
        let e = self.loss(w) - self.f_star;
        if e.is_nan() {
            f64::INFINITY
        } else {
            e.max(0.0)
        }
    }

    /// Feature dimension d.
    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// Data rows m.
    pub fn m(&self) -> usize {
        self.x.rows()
    }
}

/// `F(w) = ||X w − y||² / (2m)`, computed fully in f64.
///
/// The *measurement* path must out-resolve the quantity it measures: the
/// stationary error floors of Fig. 2 sit orders of magnitude below `F*`,
/// so the residual is accumulated in f64 (an f32 `X w` at `|Xw| ≈ 3·10³`
/// carries ~2·10⁻⁴ absolute noise — enough to bury the floors).
pub fn loss(x: &Matrix, y: &[f32], w: &[f32]) -> f64 {
    let m = x.rows();
    let d = x.cols();
    let mut acc = 0.0f64;
    for i in 0..m {
        let row = x.row(i);
        let mut dot = 0.0f64;
        for j in 0..d {
            dot += row[j] as f64 * w[j] as f64;
        }
        let e = dot - y[i] as f64;
        acc += e * e;
    }
    acc / (2.0 * m as f64)
}

/// [`loss`] for an f64 model vector (used for `F*` itself).
pub fn loss_f64w(x: &Matrix, y: &[f32], w: &[f64]) -> f64 {
    let m = x.rows();
    let d = x.cols();
    let mut acc = 0.0f64;
    for i in 0..m {
        let row = x.row(i);
        let mut dot = 0.0f64;
        for j in 0..d {
            dot += row[j] as f64 * w[j];
        }
        let e = dot - y[i] as f64;
        acc += e * e;
    }
    acc / (2.0 * m as f64)
}

/// Full gradient `∇F(w) = Xᵀ(Xw − y)/m` (reference implementation used by
/// tests and by gradient-descent baselines).
pub fn full_gradient(x: &Matrix, y: &[f32], w: &[f32], out: &mut [f32]) {
    let m = x.rows();
    let mut r = vec![0.0f32; m];
    gemv(1.0, x, w, 0.0, &mut r);
    for i in 0..m {
        r[i] -= y[i];
    }
    gemv_t(1.0 / m as f32, x, &r, 0.0, out);
}

/// Squared distance `||a − b||²` (used by convergence diagnostics).
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        let e = *x as f64 - *y as f64;
        acc += e * e;
    }
    acc
}

/// Convenience: `⟨a, b⟩` on f32 slices with f64 accumulation.
pub fn inner(a: &[f32], b: &[f32]) -> f64 {
    dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticConfig;
    use crate::data::SyntheticDataset;

    fn problem(m: usize, d: usize, seed: u64) -> LinRegProblem {
        let ds = SyntheticDataset::generate(
            SyntheticConfig { m, d, ..Default::default() },
            seed,
        );
        LinRegProblem::new(&ds)
    }

    #[test]
    fn f_star_is_noise_floor() {
        // With y = <x, w̄> + N(0,1), F* ≈ 1/2 (m >> d).
        let p = problem(2000, 100, 1);
        assert!(p.f_star > 0.2 && p.f_star < 0.8, "F*={}", p.f_star);
    }

    #[test]
    fn w_star_is_stationary() {
        let p = problem(500, 20, 2);
        let mut g = vec![0.0f32; 20];
        full_gradient(&p.x, &p.y, &p.w_star, &mut g);
        let gnorm = crate::linalg::nrm2(&g);
        // Gradient scale at w=0 is ~1e5; stationary means many orders less.
        assert!(gnorm < 1.0, "|grad(w*)| = {gnorm}");
    }

    #[test]
    fn loss_dominates_f_star_elsewhere() {
        let p = problem(500, 20, 3);
        let w0 = vec![0.0f32; 20];
        assert!(p.loss(&w0) > p.f_star);
        assert!(p.error(&w0) > 0.0);
        // w* narrowed to f32 costs a measurable but tiny amount of loss;
        // the f64 optimum is exact by construction.
        assert!(p.error(&p.w_star) < 1e-4, "{}", p.error(&p.w_star));
        let e64: f64 = {
            let w32: Vec<f32> =
                p.w_star_f64.iter().map(|&v| v as f32).collect();
            p.error(&w32)
        };
        assert!(e64 >= 0.0);
    }

    #[test]
    fn gd_converges_toward_w_star() {
        let p = problem(200, 10, 4);
        let mut w = vec![0.0f32; 10];
        let mut g = vec![0.0f32; 10];
        // eta < 2/λmax(XᵀX/m); for d=10 ints in 1..=10, λmax ≈ 310.
        let eta = 0.003;
        let e0 = p.error(&w);
        for _ in 0..500 {
            full_gradient(&p.x, &p.y, &w, &mut g);
            for j in 0..10 {
                w[j] -= eta * g[j];
            }
        }
        assert!(p.error(&w) < e0 * 1e-3, "{} -> {}", e0, p.error(&w));
    }

    #[test]
    fn dist_sq_basic() {
        assert_eq!(dist_sq(&[0.0, 3.0], &[4.0, 0.0]), 25.0);
    }
}
