//! Algorithm 1 — adaptive fastest-k SGD via a Pflug-style sign statistic.
//!
//! The statistic: during the transient phase consecutive stochastic
//! gradients tend to point the same way (`⟨ĝ_j, ĝ_{j−1}⟩ > 0`); near the
//! stationary phase the iterates oscillate around w* and the inner product
//! turns negative about half the time. A counter adds 1 on a negative
//! product and subtracts 1 on a positive one; once it exceeds `thresh`
//! (after a `burnin` number of iterations since the last switch), the
//! policy declares the phase transition and raises k by `step`, then
//! resets both counters — exactly the pseudo-code of Algorithm 1.

use super::{clamp_k, IterationObs, KPolicy};

/// Adaptation parameters of Algorithm 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PflugParams {
    /// Starting k (paper: 10 in Fig. 2, 1 in Fig. 3).
    pub k0: usize,
    /// Increment added at each detected transition (paper: `step`).
    pub step: usize,
    /// Counter threshold (paper: `thresh`, 10 in both figures).
    pub thresh: i64,
    /// Minimum iterations between switches (paper: `burnin`,
    /// 0.1 × data points = 200 in both figures).
    pub burnin: u64,
    /// Cap on k (paper stops at 40 resp. 36, i.e. below n).
    pub k_max: usize,
}

impl Default for PflugParams {
    fn default() -> Self {
        // Fig. 2 settings.
        Self { k0: 10, step: 10, thresh: 10, burnin: 200, k_max: 40 }
    }
}

/// Algorithm 1 state machine.
#[derive(Debug, Clone)]
pub struct AdaptivePflug {
    n: usize,
    params: PflugParams,
    k: usize,
    count_negative: i64,
    count_iter: u64,
    /// Switch log: (iteration, time, new k) — exposed for figures.
    switches: Vec<(u64, f64, usize)>,
}

impl AdaptivePflug {
    /// New policy for `n` workers.
    pub fn new(n: usize, params: PflugParams) -> Self {
        assert!(params.k0 >= 1 && params.k0 <= n, "k0 must be in 1..=n");
        assert!(params.step >= 1, "step must be >= 1");
        assert!(params.k_max <= n, "k_max must be <= n");
        Self {
            n,
            params,
            k: params.k0,
            count_negative: 0,
            count_iter: 1,
            switches: Vec::new(),
        }
    }

    /// The switch log: (iteration, wall-clock, new k).
    pub fn switches(&self) -> &[(u64, f64, usize)] {
        &self.switches
    }

    /// Current counter value (diagnostics).
    pub fn counter(&self) -> i64 {
        self.count_negative
    }
}

impl KPolicy for AdaptivePflug {
    fn initial_k(&self) -> usize {
        self.params.k0
    }

    fn next_k(&mut self, obs: &IterationObs) -> usize {
        // Sign statistic on ⟨ĝ_j, ĝ_{j−1}⟩ (skipped on the first iteration,
        // which has no predecessor).
        if let Some(ip) = obs.grad_inner_prev {
            if ip < 0.0 {
                self.count_negative += 1;
            } else {
                self.count_negative -= 1;
            }
        }

        // Algorithm 1's guard: `k <= k_max - step` keeps k from exceeding
        // the cap after the increment.
        if self.count_negative > self.params.thresh
            && self.count_iter > self.params.burnin
            && self.k + self.params.step <= self.params.k_max
        {
            self.k = clamp_k(self.k + self.params.step, self.n);
            self.count_negative = 0;
            self.count_iter = 0;
            self.switches.push((obs.iteration, obs.time, self.k));
        }
        self.count_iter += 1;
        self.k
    }

    fn name(&self) -> String {
        let p = &self.params;
        format!(
            "adaptive-pflug(k0={}, step={}, thresh={}, burnin={}, kmax={})",
            p.k0, p.step, p.thresh, p.burnin, p.k_max
        )
    }

    fn reset(&mut self) {
        self.k = self.params.k0;
        self.count_negative = 0;
        self.count_iter = 1;
        self.switches.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(iteration: u64, inner: f64) -> IterationObs {
        IterationObs {
            iteration,
            time: iteration as f64,
            k_used: 1,
            grad_inner_prev: Some(inner),
            grad_norm_sq: 1.0,
        }
    }

    fn params() -> PflugParams {
        PflugParams { k0: 1, step: 5, thresh: 3, burnin: 10, k_max: 16 }
    }

    #[test]
    fn stays_during_transient() {
        // All-positive inner products: no switch ever.
        let mut p = AdaptivePflug::new(20, params());
        for j in 0..1000 {
            assert_eq!(p.next_k(&obs(j, 1.0)), 1);
        }
        assert!(p.switches().is_empty());
    }

    #[test]
    fn switches_on_stationary_signal() {
        // All-negative inner products: counter grows; switch once both the
        // threshold and burn-in are satisfied.
        let mut p = AdaptivePflug::new(20, params());
        let mut first_switch = None;
        for j in 0..60 {
            let k = p.next_k(&obs(j, -1.0));
            if k > 1 && first_switch.is_none() {
                first_switch = Some(j);
            }
        }
        // Burn-in is 10 iterations; threshold 3 — the switch must happen
        // at iteration >= 10 and k jumps exactly by step.
        let j = first_switch.expect("must switch");
        assert!(j >= 10, "switched too early at {j}");
        assert_eq!(p.switches()[0].2, 6);
    }

    #[test]
    fn burnin_spaces_out_switches() {
        let mut p = AdaptivePflug::new(64, PflugParams {
            k0: 1, step: 1, thresh: 2, burnin: 20, k_max: 64,
        });
        let mut switch_iters = Vec::new();
        for j in 0..200 {
            let before = p.switches().len();
            p.next_k(&obs(j, -1.0));
            if p.switches().len() > before {
                switch_iters.push(j);
            }
        }
        assert!(switch_iters.len() >= 2);
        for w in switch_iters.windows(2) {
            assert!(w[1] - w[0] > 20, "switches too close: {switch_iters:?}");
        }
    }

    #[test]
    fn counter_decrements_on_positive() {
        let mut p = AdaptivePflug::new(20, params());
        p.next_k(&obs(0, -1.0));
        p.next_k(&obs(1, -1.0));
        assert_eq!(p.counter(), 2);
        p.next_k(&obs(2, 1.0));
        assert_eq!(p.counter(), 1);
    }

    #[test]
    fn respects_k_max() {
        let mut p = AdaptivePflug::new(20, PflugParams {
            k0: 1, step: 5, thresh: 1, burnin: 0, k_max: 11,
        });
        for j in 0..500 {
            p.next_k(&obs(j, -1.0));
        }
        // k0=1 → 6 → 11; next step would exceed k_max=11, so it stops.
        assert_eq!(p.switches().last().unwrap().2, 11);
    }

    #[test]
    fn reset_restores_initial_state() {
        let mut p = AdaptivePflug::new(20, params());
        for j in 0..60 {
            p.next_k(&obs(j, -1.0));
        }
        assert!(!p.switches().is_empty());
        p.reset();
        assert_eq!(p.initial_k(), 1);
        assert!(p.switches().is_empty());
        assert_eq!(p.counter(), 0);
    }

    #[test]
    fn first_iteration_without_inner_product_is_neutral() {
        let mut p = AdaptivePflug::new(20, params());
        let o = IterationObs {
            iteration: 0,
            time: 0.0,
            k_used: 1,
            grad_inner_prev: None,
            grad_norm_sq: 1.0,
        };
        assert_eq!(p.next_k(&o), 1);
        assert_eq!(p.counter(), 0);
    }
}
