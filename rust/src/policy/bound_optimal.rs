//! Theorem-1 oracle policy: switch k at the precomputed bound-optimal
//! wall-clock times.
//!
//! Requires full knowledge of the system parameters (η, L, c, σ², s,
//! F(w₀) − F*) and the delay model's order statistics — the paper's point
//! is precisely that this is impractical, which Algorithm 1 fixes; we keep
//! the oracle as a comparator and for Fig. 1.

use super::{clamp_k, IterationObs, KPolicy};
use crate::theory::{switching_times, ErrorBound};

/// Time-triggered bound-optimal switching (Theorem 1).
#[derive(Debug, Clone)]
pub struct BoundOptimal {
    n: usize,
    /// Ascending switch times t_1 … t_{n−1}; entry i moves k to i + 2.
    times: Vec<f64>,
    k: usize,
}

impl BoundOptimal {
    /// Precompute the Theorem-1 schedule from the bound.
    pub fn new(bound: &ErrorBound) -> Self {
        let times = switching_times(bound).iter().map(|s| s.time).collect();
        Self { n: bound.order().n(), times, k: 1 }
    }

    /// Build directly from precomputed times (tests / custom schedules).
    pub fn from_times(n: usize, times: Vec<f64>) -> Self {
        assert!(times.len() == n - 1, "need n-1 switch times");
        Self { n, times, k: 1 }
    }

    /// The switch schedule.
    pub fn times(&self) -> &[f64] {
        &self.times
    }
}

impl KPolicy for BoundOptimal {
    fn initial_k(&self) -> usize {
        1
    }

    fn next_k(&mut self, obs: &IterationObs) -> usize {
        // k(t) = 1 + #{switch times <= t}; times are sorted.
        let passed = self.times.iter().take_while(|&&t| t <= obs.time).count();
        self.k = clamp_k(1 + passed, self.n);
        self.k
    }

    fn name(&self) -> String {
        format!("bound-optimal(n={})", self.n)
    }

    fn reset(&mut self) {
        self.k = 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::OrderStats;
    use crate::theory::{BoundParams, ErrorBound};

    fn obs_at(time: f64) -> IterationObs {
        IterationObs {
            iteration: 0,
            time,
            k_used: 1,
            grad_inner_prev: None,
            grad_norm_sq: 0.0,
        }
    }

    #[test]
    fn follows_schedule() {
        let mut p = BoundOptimal::from_times(4, vec![10.0, 20.0, 30.0]);
        assert_eq!(p.initial_k(), 1);
        assert_eq!(p.next_k(&obs_at(5.0)), 1);
        assert_eq!(p.next_k(&obs_at(10.0)), 2);
        assert_eq!(p.next_k(&obs_at(25.0)), 3);
        assert_eq!(p.next_k(&obs_at(1e9)), 4);
    }

    #[test]
    fn k_is_monotone_under_monotone_time() {
        let b = ErrorBound::new(
            BoundParams::example1(),
            OrderStats::exponential(5, 5.0),
        );
        let mut p = BoundOptimal::new(&b);
        let mut prev_k = 0;
        for i in 0..1000 {
            let k = p.next_k(&obs_at(i as f64 * 20.0));
            assert!(k >= prev_k);
            assert!(k <= 5);
            prev_k = k;
        }
        assert_eq!(prev_k, 5, "should eventually reach k = n");
    }
}
