//! Non-adaptive fastest-k (the Fig. 2 baseline).

use super::{IterationObs, KPolicy};

/// Always wait for the same k workers.
#[derive(Debug, Clone, Copy)]
pub struct FixedK {
    k: usize,
}

impl FixedK {
    /// Fixed k (must be >= 1).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "k must be >= 1");
        Self { k }
    }
}

impl KPolicy for FixedK {
    fn initial_k(&self) -> usize {
        self.k
    }
    fn next_k(&mut self, _obs: &IterationObs) -> usize {
        self.k
    }
    fn name(&self) -> String {
        format!("fixed(k={})", self.k)
    }
    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_changes() {
        let mut p = FixedK::new(7);
        assert_eq!(p.initial_k(), 7);
        let obs = IterationObs {
            iteration: 3,
            time: 10.0,
            k_used: 7,
            grad_inner_prev: Some(-1.0),
            grad_norm_sq: 1.0,
        };
        for _ in 0..100 {
            assert_eq!(p.next_k(&obs), 7);
        }
    }

    #[test]
    #[should_panic(expected = "k must be >= 1")]
    fn rejects_zero() {
        FixedK::new(0);
    }
}
