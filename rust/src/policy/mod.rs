//! k-adaptation policies — the paper's system contribution.
//!
//! A [`KPolicy`] decides, after every completed iteration, how many of the
//! n workers the master waits for on the *next* iteration:
//!
//! * [`FixedK`] — non-adaptive fastest-k (the baseline of Fig. 2),
//! * [`AdaptivePflug`] — Algorithm 1: the Pflug-style sign statistic on
//!   consecutive gradient inner products, oblivious to system parameters,
//! * [`BoundOptimal`] — Theorem 1: switch at the precomputed bound-optimal
//!   wall-clock times (requires the system parameters; used for Fig. 1
//!   and as an oracle comparator),
//! * [`TimeSchedule`] — arbitrary user-supplied `(time, k)` switch points.
//!
//! The master feeds policies an [`IterationObs`] containing the inner
//! product `⟨ĝ_j, ĝ_{j−1}⟩` (computed once in the loop, so policies stay
//! O(1) per iteration).

mod adaptive_pflug;
mod bound_optimal;
mod fixed;
mod schedule;
mod variance_test;

pub use adaptive_pflug::{AdaptivePflug, PflugParams};
pub use bound_optimal::BoundOptimal;
pub use fixed::FixedK;
pub use schedule::TimeSchedule;
pub use variance_test::{VarianceTest, VarianceTestParams};

/// What a policy sees after each iteration.
#[derive(Debug, Clone, Copy)]
pub struct IterationObs {
    /// Completed iteration index j (0-based).
    pub iteration: u64,
    /// Wall-clock time after this iteration.
    pub time: f64,
    /// k used for this iteration.
    pub k_used: usize,
    /// `⟨ĝ_j, ĝ_{j−1}⟩` — `None` on the first iteration.
    pub grad_inner_prev: Option<f64>,
    /// `||ĝ_j||²` (diagnostics; used by variance-test extensions).
    pub grad_norm_sq: f64,
}

/// A k-selection policy.
pub trait KPolicy: Send {
    /// k for the first iteration.
    fn initial_k(&self) -> usize;

    /// k for the next iteration, given what just happened.
    fn next_k(&mut self, obs: &IterationObs) -> usize;

    /// Display name for metrics/reports.
    fn name(&self) -> String;

    /// Reset internal state (policies are reused across repetitions).
    fn reset(&mut self);
}

/// Clamp a k value into `1..=n`.
pub(crate) fn clamp_k(k: usize, n: usize) -> usize {
    k.max(1).min(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(iteration: u64, inner: Option<f64>) -> IterationObs {
        IterationObs {
            iteration,
            time: iteration as f64,
            k_used: 1,
            grad_inner_prev: inner,
            grad_norm_sq: 1.0,
        }
    }

    #[test]
    fn policies_are_object_safe() {
        let mut policies: Vec<Box<dyn KPolicy>> = vec![
            Box::new(FixedK::new(3)),
            Box::new(AdaptivePflug::new(
                50,
                PflugParams { k0: 1, step: 5, thresh: 10, burnin: 20, k_max: 50 },
            )),
            Box::new(TimeSchedule::new(1, vec![(10.0, 5)])),
        ];
        for p in policies.iter_mut() {
            assert!(p.initial_k() >= 1);
            let k = p.next_k(&obs(0, None));
            assert!(k >= 1);
            p.reset();
        }
    }
}
