//! Explicit `(time, k)` switch schedule — for reproducing hand-tuned
//! schedules and for ablations that isolate *when* to switch from *how*
//! the decision is made.

use super::{clamp_k, IterationObs, KPolicy};

/// User-supplied time-triggered schedule.
#[derive(Debug, Clone)]
pub struct TimeSchedule {
    k0: usize,
    /// Ascending (time, k) switch points.
    points: Vec<(f64, usize)>,
    n: usize,
}

impl TimeSchedule {
    /// `k0` until the first switch time; each `(t, k)` applies from t on.
    pub fn new(k0: usize, points: Vec<(f64, usize)>) -> Self {
        assert!(k0 >= 1, "k0 must be >= 1");
        assert!(
            points.windows(2).all(|w| w[0].0 <= w[1].0),
            "switch times must be ascending"
        );
        let n = points.iter().map(|&(_, k)| k).max().unwrap_or(k0).max(k0);
        Self { k0, points, n }
    }
}

impl KPolicy for TimeSchedule {
    fn initial_k(&self) -> usize {
        self.k0
    }

    fn next_k(&mut self, obs: &IterationObs) -> usize {
        let mut k = self.k0;
        for &(t, kk) in &self.points {
            if obs.time >= t {
                k = kk;
            } else {
                break;
            }
        }
        clamp_k(k, self.n)
    }

    fn name(&self) -> String {
        format!("schedule(k0={}, {} switches)", self.k0, self.points.len())
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs_at(time: f64) -> IterationObs {
        IterationObs {
            iteration: 0,
            time,
            k_used: 1,
            grad_inner_prev: None,
            grad_norm_sq: 0.0,
        }
    }

    #[test]
    fn applies_points_in_order() {
        let mut p = TimeSchedule::new(2, vec![(5.0, 4), (9.0, 8)]);
        assert_eq!(p.next_k(&obs_at(0.0)), 2);
        assert_eq!(p.next_k(&obs_at(5.0)), 4);
        assert_eq!(p.next_k(&obs_at(8.9)), 4);
        assert_eq!(p.next_k(&obs_at(9.0)), 8);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn rejects_unsorted() {
        TimeSchedule::new(1, vec![(5.0, 2), (1.0, 3)]);
    }

    #[test]
    fn empty_schedule_is_fixed_k() {
        let mut p = TimeSchedule::new(3, vec![]);
        assert_eq!(p.next_k(&obs_at(100.0)), 3);
    }
}
