//! Variance-ratio adaptive policy — an alternative stationarity detector.
//!
//! Complements Algorithm 1's sign test with the other classic diagnostic
//! (cf. Chee & Toulis 2018): in the transient phase the gradient norm is
//! dominated by the deterministic drift, so the *relative variance* of
//! `||ĝ_j||²` over a sliding window is small; in the stationary phase the
//! drift vanishes and consecutive gradient norms fluctuate at O(1)
//! relative scale while their running mean stops shrinking. We declare a
//! transition when the windowed mean of `||ĝ||²` stops decreasing
//! (relative improvement < `min_drop`) — and raise k, like Algorithm 1.
//!
//! Used by the ablation benches to show the *detector* is swappable while
//! the fastest-k machinery stays fixed.

use super::{clamp_k, IterationObs, KPolicy};

/// Parameters for the variance/plateau detector.
#[derive(Debug, Clone, Copy)]
pub struct VarianceTestParams {
    /// Starting k.
    pub k0: usize,
    /// Increment per detected transition.
    pub step: usize,
    /// Sliding-window length (iterations).
    pub window: usize,
    /// Declare a plateau when the windowed mean of `||ĝ||²` fails to drop
    /// by at least this relative amount vs the previous window.
    pub min_drop: f64,
    /// Minimum iterations between switches.
    pub burnin: u64,
    /// Cap on k.
    pub k_max: usize,
}

impl Default for VarianceTestParams {
    fn default() -> Self {
        Self { k0: 10, step: 10, window: 50, min_drop: 0.05, burnin: 200, k_max: 40 }
    }
}

/// Plateau-detecting adaptive policy.
#[derive(Debug, Clone)]
pub struct VarianceTest {
    n: usize,
    params: VarianceTestParams,
    k: usize,
    buf: Vec<f64>,
    prev_window_mean: Option<f64>,
    since_switch: u64,
    switches: Vec<(u64, f64, usize)>,
}

impl VarianceTest {
    /// New policy over n workers.
    pub fn new(n: usize, params: VarianceTestParams) -> Self {
        assert!(params.k0 >= 1 && params.k0 <= n);
        assert!(params.window >= 2);
        Self {
            n,
            params,
            k: params.k0,
            buf: Vec::with_capacity(params.window),
            prev_window_mean: None,
            since_switch: 0,
            switches: Vec::new(),
        }
    }

    /// Switch log.
    pub fn switches(&self) -> &[(u64, f64, usize)] {
        &self.switches
    }
}

impl KPolicy for VarianceTest {
    fn initial_k(&self) -> usize {
        self.params.k0
    }

    fn next_k(&mut self, obs: &IterationObs) -> usize {
        self.since_switch += 1;
        self.buf.push(obs.grad_norm_sq);
        if self.buf.len() >= self.params.window {
            let mean: f64 =
                self.buf.iter().sum::<f64>() / self.buf.len() as f64;
            if let Some(prev) = self.prev_window_mean {
                let drop = (prev - mean) / prev.max(f64::MIN_POSITIVE);
                if drop < self.params.min_drop
                    && self.since_switch > self.params.burnin
                    && self.k + self.params.step <= self.params.k_max
                {
                    self.k = clamp_k(self.k + self.params.step, self.n);
                    self.switches.push((obs.iteration, obs.time, self.k));
                    self.since_switch = 0;
                    self.prev_window_mean = None;
                    self.buf.clear();
                    return self.k;
                }
            }
            self.prev_window_mean = Some(mean);
            self.buf.clear();
        }
        self.k
    }

    fn name(&self) -> String {
        let p = &self.params;
        format!(
            "variance-test(k0={}, step={}, window={}, min_drop={})",
            p.k0, p.step, p.window, p.min_drop
        )
    }

    fn reset(&mut self) {
        self.k = self.params.k0;
        self.buf.clear();
        self.prev_window_mean = None;
        self.since_switch = 0;
        self.switches.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(j: u64, gnorm: f64) -> IterationObs {
        IterationObs {
            iteration: j,
            time: j as f64,
            k_used: 1,
            grad_inner_prev: Some(0.0),
            grad_norm_sq: gnorm,
        }
    }

    fn params() -> VarianceTestParams {
        VarianceTestParams {
            k0: 2,
            step: 4,
            window: 10,
            min_drop: 0.05,
            burnin: 15,
            k_max: 20,
        }
    }

    #[test]
    fn no_switch_while_norm_decays() {
        let mut p = VarianceTest::new(20, params());
        for j in 0..500 {
            // Exponentially shrinking gradient norms: always a big drop.
            let k = p.next_k(&obs(j, 100.0 * (-0.05 * j as f64).exp()));
            assert_eq!(k, 2, "j={j}");
        }
    }

    #[test]
    fn switches_on_plateau() {
        let mut p = VarianceTest::new(20, params());
        let mut switched_at = None;
        for j in 0..200 {
            let k = p.next_k(&obs(j, 1.0)); // flat norms: plateau
            if k > 2 && switched_at.is_none() {
                switched_at = Some(j);
            }
        }
        let j = switched_at.expect("plateau must trigger a switch");
        assert!(j >= 15, "burn-in must be respected (j={j})");
        assert_eq!(p.switches()[0].2, 6);
    }

    #[test]
    fn respects_k_max() {
        let mut p = VarianceTest::new(20, VarianceTestParams {
            burnin: 0,
            ..params()
        });
        for j in 0..5000 {
            p.next_k(&obs(j, 1.0));
        }
        let final_k = p.switches().last().unwrap().2;
        assert!(final_k <= 20 && final_k + 4 > 20, "final_k={final_k}");
    }

    #[test]
    fn reset_clears_state() {
        let mut p = VarianceTest::new(20, params());
        for j in 0..200 {
            p.next_k(&obs(j, 1.0));
        }
        assert!(!p.switches().is_empty());
        p.reset();
        assert!(p.switches().is_empty());
        assert_eq!(p.initial_k(), 2);
    }
}
