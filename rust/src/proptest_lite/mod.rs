//! Property-testing harness (proptest is not available offline).
//!
//! A [`Runner`] drives a property over `cases` random inputs produced by a
//! [`Gen`]; on failure it *shrinks* the input with the generator's
//! `shrink` candidates before reporting the minimal counterexample. Used
//! by `rust/tests/proptests.rs` for coordinator invariants (routing,
//! selection, policy state machines).

use crate::rng::{Pcg64, Rng};

/// A random-input generator with optional shrinking.
pub trait Gen {
    /// Generated value type.
    type Value: Clone + std::fmt::Debug;

    /// Produce a random value.
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;

    /// Smaller candidates for a failing value (simplest first). Default:
    /// no shrinking.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform usize range generator `[lo, hi]` shrinking toward `lo`.
pub struct UsizeRange {
    /// Inclusive lower bound.
    pub lo: usize,
    /// Inclusive upper bound.
    pub hi: usize,
}

impl Gen for UsizeRange {
    type Value = usize;
    fn generate(&self, rng: &mut Pcg64) -> usize {
        rng.gen_range_u64(self.lo as u64, self.hi as u64) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// f64 range generator shrinking toward the low end.
pub struct F64Range {
    /// Lower bound.
    pub lo: f64,
    /// Upper bound.
    pub hi: f64,
}

impl Gen for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Pcg64) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        if *v > self.lo {
            vec![self.lo, self.lo + (*v - self.lo) / 2.0]
        } else {
            Vec::new()
        }
    }
}

/// Vec-of-f64 generator (random length) shrinking by halving the tail.
pub struct VecF64 {
    /// Minimum length.
    pub min_len: usize,
    /// Maximum length.
    pub max_len: usize,
    /// Element range.
    pub lo: f64,
    /// Element range.
    pub hi: f64,
}

impl Gen for VecF64 {
    type Value = Vec<f64>;
    fn generate(&self, rng: &mut Pcg64) -> Vec<f64> {
        let len =
            rng.gen_range_u64(self.min_len as u64, self.max_len as u64) as usize;
        (0..len)
            .map(|_| self.lo + (self.hi - self.lo) * rng.next_f64())
            .collect()
    }
    fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        out
    }
}

/// Pair combinator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(a)
            .into_iter()
            .map(|a2| (a2, b.clone()))
            .collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult<V> {
    /// All cases passed.
    Pass,
    /// A (shrunk) counterexample.
    Fail {
        /// The minimal failing input found.
        minimal: V,
        /// Failure message of the original (pre-shrink) case.
        message: String,
        /// Shrink steps taken.
        shrinks: usize,
    },
}

/// Property runner.
pub struct Runner {
    /// Number of random cases.
    pub cases: usize,
    /// RNG seed (fixed ⇒ reproducible failures).
    pub seed: u64,
    /// Maximum shrink iterations.
    pub max_shrinks: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Self { cases: 100, seed: 0x9E37, max_shrinks: 200 }
    }
}

impl Runner {
    /// Run `prop` over random inputs; returns the shrunk counterexample on
    /// failure. `prop` returns `Err(message)` to signal failure.
    pub fn run<G: Gen>(
        &self,
        gen: &G,
        prop: impl Fn(&G::Value) -> Result<(), String>,
    ) -> PropResult<G::Value> {
        let mut rng = Pcg64::seed_stream(self.seed, 0x9907);
        for _ in 0..self.cases {
            let value = gen.generate(&mut rng);
            if let Err(message) = prop(&value) {
                // Shrink.
                let mut minimal = value;
                let mut shrinks = 0;
                'outer: while shrinks < self.max_shrinks {
                    for cand in gen.shrink(&minimal) {
                        if prop(&cand).is_err() {
                            minimal = cand;
                            shrinks += 1;
                            continue 'outer;
                        }
                    }
                    break;
                }
                return PropResult::Fail { minimal, message, shrinks };
            }
        }
        PropResult::Pass
    }

    /// Panic with the counterexample on failure (test-friendly wrapper).
    pub fn check<G: Gen>(
        &self,
        name: &str,
        gen: &G,
        prop: impl Fn(&G::Value) -> Result<(), String>,
    ) {
        if let PropResult::Fail { minimal, message, shrinks } =
            self.run(gen, prop)
        {
            panic!(
                "property '{name}' failed: {message}\n  minimal \
                 counterexample (after {shrinks} shrinks): {minimal:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let r = Runner::default();
        r.check("le", &UsizeRange { lo: 0, hi: 100 }, |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let r = Runner { cases: 500, ..Default::default() };
        match r.run(&UsizeRange { lo: 0, hi: 1000 }, |&v| {
            if v < 17 {
                Ok(())
            } else {
                Err(format!("{v} >= 17"))
            }
        }) {
            PropResult::Fail { minimal, .. } => {
                // Shrinker should land near the boundary.
                assert!(minimal >= 17 && minimal <= 30, "minimal={minimal}");
            }
            PropResult::Pass => panic!("should have failed"),
        }
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecF64 { min_len: 2, max_len: 10, lo: -1.0, hi: 1.0 };
        let mut rng = Pcg64::seed(1);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!(v.len() >= 2 && v.len() <= 10);
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        }
    }

    #[test]
    fn pair_combinator_shrinks_both_sides() {
        let g = Pair(UsizeRange { lo: 0, hi: 10 }, UsizeRange { lo: 5, hi: 9 });
        let shrunk = g.shrink(&(10, 9));
        assert!(shrunk.iter().any(|&(a, _)| a < 10));
        assert!(shrunk.iter().any(|&(_, b)| b < 9));
    }
}
