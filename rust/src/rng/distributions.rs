//! Continuous/discrete distributions over any [`Rng`](super::Rng).
//!
//! These are the building blocks of the straggler delay models
//! (`straggler::*`) and the synthetic data generator (`data::synthetic`).
//! All samplers use inverse-CDF or Box–Muller forms chosen for numerical
//! robustness rather than peak speed — delay sampling is nowhere near the
//! hot path (one draw per worker per iteration).

use super::Rng;

/// A sampleable distribution.
pub trait Distribution {
    /// Draw one sample.
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64;

    /// Analytic mean, if finite.
    fn mean(&self) -> f64;

    /// Analytic variance, if finite.
    fn variance(&self) -> f64;
}

/// Uniform on `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi > lo, "Uniform requires hi > lo");
        Self { lo, hi }
    }
}

impl Distribution for Uniform {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
    fn mean(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }
    fn variance(&self) -> f64 {
        let w = self.hi - self.lo;
        w * w / 12.0
    }
}

/// Exponential with rate `lambda` (mean `1/lambda`) — the paper's §V model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Exponential {
    pub lambda: f64,
}

impl Exponential {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0, "Exponential requires lambda > 0");
        Self { lambda }
    }

    /// Inverse CDF `F⁻¹(p)` for `p ∈ [0, 1)`.
    #[inline]
    pub fn quantile(&self, p: f64) -> f64 {
        self.quantile_tail(1.0 - p)
    }

    /// Upper-tail inverse `S⁻¹(s) = F⁻¹(1 − s)` for `s ∈ (0, 1]` — the
    /// numerically stable form for order-statistics sampling, where the
    /// survival mass `s` is tracked directly (no `1 − p` cancellation).
    /// `quantile_tail(u)` over `u ~ Uniform(0, 1]` is exactly the
    /// [`Distribution::sample`] draw.
    #[inline]
    pub fn quantile_tail(&self, s: f64) -> f64 {
        -s.ln() / self.lambda
    }
}

impl Distribution for Exponential {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        // Inverse CDF on the open interval so ln() never sees 0.
        -rng.next_f64_open().ln() / self.lambda
    }
    fn mean(&self) -> f64 {
        1.0 / self.lambda
    }
    fn variance(&self) -> f64 {
        1.0 / (self.lambda * self.lambda)
    }
}

/// Normal via Box–Muller (both variates cached would complicate the trait;
/// we draw fresh — fine off the hot path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Normal {
    pub mu: f64,
    pub sigma: f64,
}

impl Normal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma >= 0.0, "Normal requires sigma >= 0");
        Self { mu, sigma }
    }

    /// Standard normal draw.
    #[inline]
    pub fn standard<R: Rng + ?Sized>(rng: &mut R) -> f64 {
        let u1 = rng.next_f64_open();
        let u2 = rng.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

impl Distribution for Normal {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.mu + self.sigma * Self::standard(rng)
    }
    fn mean(&self) -> f64 {
        self.mu
    }
    fn variance(&self) -> f64 {
        self.sigma * self.sigma
    }
}

/// Pareto (Type I) with scale `xm > 0` and shape `alpha > 0` — heavy-tailed
/// straggling; mean finite iff `alpha > 1`, variance iff `alpha > 2`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pareto {
    pub xm: f64,
    pub alpha: f64,
}

impl Pareto {
    pub fn new(xm: f64, alpha: f64) -> Self {
        assert!(xm > 0.0 && alpha > 0.0, "Pareto requires xm, alpha > 0");
        Self { xm, alpha }
    }

    /// Inverse CDF `F⁻¹(p)` for `p ∈ [0, 1)`.
    #[inline]
    pub fn quantile(&self, p: f64) -> f64 {
        self.quantile_tail(1.0 - p)
    }

    /// Upper-tail inverse `S⁻¹(s)` for `s ∈ (0, 1]` (see
    /// [`Exponential::quantile_tail`]); matches
    /// [`Distribution::sample`] over `s ~ Uniform(0, 1]`.
    #[inline]
    pub fn quantile_tail(&self, s: f64) -> f64 {
        self.xm / s.powf(1.0 / self.alpha)
    }
}

impl Distribution for Pareto {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.xm / rng.next_f64_open().powf(1.0 / self.alpha)
    }
    fn mean(&self) -> f64 {
        if self.alpha > 1.0 {
            self.alpha * self.xm / (self.alpha - 1.0)
        } else {
            f64::INFINITY
        }
    }
    fn variance(&self) -> f64 {
        if self.alpha > 2.0 {
            let a = self.alpha;
            self.xm * self.xm * a / ((a - 1.0) * (a - 1.0) * (a - 2.0))
        } else {
            f64::INFINITY
        }
    }
}

/// Weibull with scale `lambda` and shape `k`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Weibull {
    pub lambda: f64,
    pub k: f64,
}

impl Weibull {
    pub fn new(lambda: f64, k: f64) -> Self {
        assert!(lambda > 0.0 && k > 0.0, "Weibull requires lambda, k > 0");
        Self { lambda, k }
    }

    /// Inverse CDF `F⁻¹(p)` for `p ∈ [0, 1)`.
    #[inline]
    pub fn quantile(&self, p: f64) -> f64 {
        self.quantile_tail(1.0 - p)
    }

    /// Upper-tail inverse `S⁻¹(s)` for `s ∈ (0, 1]` (see
    /// [`Exponential::quantile_tail`]); matches
    /// [`Distribution::sample`] over `s ~ Uniform(0, 1]`.
    #[inline]
    pub fn quantile_tail(&self, s: f64) -> f64 {
        self.lambda * (-s.ln()).powf(1.0 / self.k)
    }
}

/// Lanczos ln-gamma (needed for Weibull moments).
pub fn ln_gamma(x: f64) -> f64 {
    // Lanczos approximation, g = 7, n = 9.
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

fn gamma_fn(x: f64) -> f64 {
    ln_gamma(x).exp()
}

impl Distribution for Weibull {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.lambda * (-rng.next_f64_open().ln()).powf(1.0 / self.k)
    }
    fn mean(&self) -> f64 {
        self.lambda * gamma_fn(1.0 + 1.0 / self.k)
    }
    fn variance(&self) -> f64 {
        let g1 = gamma_fn(1.0 + 1.0 / self.k);
        let g2 = gamma_fn(1.0 + 2.0 / self.k);
        self.lambda * self.lambda * (g2 - g1 * g1)
    }
}

/// Bernoulli over {0, 1}.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bernoulli {
    pub p: f64,
}

impl Bernoulli {
    pub fn new(p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "Bernoulli requires p in [0,1]");
        Self { p }
    }

    /// Boolean draw.
    #[inline]
    pub fn flip<R: Rng + ?Sized>(&self, rng: &mut R) -> bool {
        rng.next_f64() < self.p
    }
}

impl Distribution for Bernoulli {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        if self.flip(rng) {
            1.0
        } else {
            0.0
        }
    }
    fn mean(&self) -> f64 {
        self.p
    }
    fn variance(&self) -> f64 {
        self.p * (1.0 - self.p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn moments<D: Distribution>(d: &D, n: usize, seed: u64) -> (f64, f64) {
        let mut rng = Pcg64::seed(seed);
        let samples: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var =
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        (mean, var)
    }

    #[test]
    fn exponential_moments() {
        let d = Exponential::new(2.0);
        let (m, v) = moments(&d, 200_000, 1);
        assert!((m - d.mean()).abs() < 0.01, "m={m}");
        assert!((v - d.variance()).abs() < 0.02, "v={v}");
    }

    #[test]
    fn normal_moments() {
        let d = Normal::new(3.0, 1.5);
        let (m, v) = moments(&d, 200_000, 2);
        assert!((m - 3.0).abs() < 0.02);
        assert!((v - 2.25).abs() < 0.05);
    }

    #[test]
    fn uniform_moments() {
        let d = Uniform::new(-1.0, 5.0);
        let (m, v) = moments(&d, 200_000, 3);
        assert!((m - 2.0).abs() < 0.02);
        assert!((v - 3.0).abs() < 0.05);
    }

    #[test]
    fn pareto_moments_alpha3() {
        let d = Pareto::new(1.0, 3.0);
        let (m, _v) = moments(&d, 400_000, 4);
        assert!((m - d.mean()).abs() < 0.02, "m={m} want {}", d.mean());
    }

    #[test]
    fn pareto_infinite_mean_flagged() {
        assert!(Pareto::new(1.0, 0.9).mean().is_infinite());
        assert!(Pareto::new(1.0, 1.5).variance().is_infinite());
    }

    #[test]
    fn weibull_moments() {
        let d = Weibull::new(2.0, 1.5);
        let (m, v) = moments(&d, 200_000, 5);
        assert!((m - d.mean()).abs() < 0.02, "m={m} want {}", d.mean());
        assert!((v - d.variance()).abs() < 0.05, "v={v} want {}", d.variance());
    }

    #[test]
    fn weibull_shape1_is_exponential() {
        let w = Weibull::new(2.0, 1.0);
        let e = Exponential::new(0.5);
        assert!((w.mean() - e.mean()).abs() < 1e-9);
        assert!((w.variance() - e.variance()).abs() < 1e-9);
    }

    #[test]
    fn bernoulli_mean() {
        let d = Bernoulli::new(0.3);
        let (m, _) = moments(&d, 100_000, 6);
        assert!((m - 0.3).abs() < 0.01);
    }

    #[test]
    fn ln_gamma_known_values() {
        // Gamma(1)=1, Gamma(2)=1, Gamma(3)=2, Gamma(0.5)=sqrt(pi)
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(3.0) - 2.0f64.ln()).abs() < 1e-10);
        assert!(
            (ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10
        );
    }

    #[test]
    fn quantile_inverts_the_cdf() {
        // F(F⁻¹(p)) = p analytically for all three delay families.
        let e = Exponential::new(2.0);
        let pa = Pareto::new(1.5, 2.5);
        let w = Weibull::new(2.0, 1.5);
        for &p in &[0.0, 0.1, 0.5, 0.9, 0.999] {
            let x = e.quantile(p);
            assert!((1.0 - (-e.lambda * x).exp() - p).abs() < 1e-12, "exp p={p}");
            let x = pa.quantile(p);
            assert!(
                (1.0 - (pa.xm / x).powf(pa.alpha) - p).abs() < 1e-12,
                "pareto p={p}"
            );
            let x = w.quantile(p);
            assert!(
                (1.0 - (-(x / w.lambda).powf(w.k)).exp() - p).abs() < 1e-12,
                "weibull p={p}"
            );
        }
        // Median sanity: exponential median = ln 2 / λ.
        assert!(
            (e.quantile(0.5) - std::f64::consts::LN_2 / 2.0).abs() < 1e-12
        );
    }

    #[test]
    fn quantile_tail_is_bitwise_the_sampler() {
        // Each sampler draws U ~ (0,1] and returns S⁻¹(U); quantile_tail
        // over the same U must reproduce the draw bit for bit.
        let e = Exponential::new(0.7);
        let pa = Pareto::new(0.5, 2.2);
        let w = Weibull::new(1.3, 0.8);
        for seed in 0..20u64 {
            let u = Pcg64::seed(seed).next_f64_open();
            assert_eq!(e.sample(&mut Pcg64::seed(seed)), e.quantile_tail(u));
            assert_eq!(pa.sample(&mut Pcg64::seed(seed)), pa.quantile_tail(u));
            assert_eq!(w.sample(&mut Pcg64::seed(seed)), w.quantile_tail(u));
        }
    }

    #[test]
    fn exponential_samples_positive() {
        let d = Exponential::new(1.0);
        let mut rng = Pcg64::seed(9);
        for _ in 0..10_000 {
            assert!(d.sample(&mut rng) > 0.0);
        }
    }
}
