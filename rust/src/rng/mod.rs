//! Pseudo-random number generation substrate.
//!
//! The build environment is offline (no `rand` crate), so the PRNG stack is
//! implemented here: a PCG-64 core generator seeded through SplitMix64, a
//! [`Rng`] trait for the primitive draws, and the continuous distributions
//! the straggler models and data generators need ([`distributions`]).
//!
//! Determinism contract: every experiment config carries a `seed`; all
//! stochastic components (delay models, data synthesis, SGD shard picks)
//! derive independent streams via [`Pcg64::stream`] so runs are exactly
//! reproducible regardless of thread scheduling.

pub mod distributions;
mod pcg;
mod splitmix;

pub use distributions::{
    Bernoulli, Distribution, Exponential, Normal, Pareto, Uniform, Weibull,
};
pub use pcg::Pcg64;
pub use splitmix::SplitMix64;

/// Minimal uniform-source trait; everything else builds on `next_u64`.
pub trait Rng {
    /// Next 64 uniformly random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits — the mantissa width of f64.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in the open interval `(0, 1]` — safe for `ln()`.
    #[inline]
    fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in `[0, bound)` via Lemire's rejection method.
    #[inline]
    fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    fn gen_range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seed(42);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_open_never_zero() {
        let mut rng = Pcg64::seed(7);
        for _ in 0..10_000 {
            assert!(rng.next_f64_open() > 0.0);
        }
    }

    #[test]
    fn next_below_respects_bound() {
        let mut rng = Pcg64::seed(3);
        for bound in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(rng.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_below_is_roughly_uniform() {
        let mut rng = Pcg64::seed(11);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.next_below(10) as usize] += 1;
        }
        for &c in &counts {
            let expected = n as f64 / 10.0;
            assert!((c as f64 - expected).abs() < 5.0 * expected.sqrt());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
