//! PCG-64 (XSL-RR 128/64) — the experiment generator.
//!
//! 128-bit LCG state with an xorshift-rotate output permutation
//! (O'Neill 2014). Chosen for: tiny state, excellent statistical quality,
//! and cheap independent *streams* (odd increments), which we use to give
//! every worker / component its own deterministic sequence.

use super::{Rng, SplitMix64};

const MULTIPLIER: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

/// PCG-64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    /// Stream selector; always odd.
    inc: u128,
}

impl Pcg64 {
    /// Seed via SplitMix64 expansion (any u64 seed is fine, including 0).
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0)
    }

    /// Independent stream `stream` of the same seed. Streams produced by
    /// different `stream` values are statistically independent sequences.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ 0xA02B_DBF7_BB3C_0A7A);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let mut sm2 = SplitMix64::new(stream ^ 0x5851_F42D_4C95_7F2D);
        let i0 = sm2.next_u64() as u128;
        let i1 = sm2.next_u64() as u128;
        let mut pcg = Self {
            state: (s0 << 64) | s1,
            inc: (((i0 << 64) | i1) << 1) | 1, // force odd
        };
        // Decorrelate the seed from the first outputs.
        pcg.state = pcg.state.wrapping_add(pcg.inc);
        let _ = pcg.next_u64();
        let _ = pcg.next_u64();
        pcg
    }

    /// Derive a child generator (new stream) — the fan-out primitive used
    /// to give each worker / component its own sequence.
    pub fn stream(&mut self, stream: u64) -> Pcg64 {
        let salt = self.next_u64();
        Pcg64::seed_stream(salt, stream)
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(MULTIPLIER)
            .wrapping_add(self.inc);
        // XSL-RR output function.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = Pcg64::seed(99);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Pcg64::seed(99);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_differ() {
        let mut r1 = Pcg64::seed(1);
        let mut r2 = Pcg64::seed(2);
        assert_ne!(
            (0..4).map(|_| r1.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| r2.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn streams_differ() {
        let mut r1 = Pcg64::seed_stream(1, 0);
        let mut r2 = Pcg64::seed_stream(1, 1);
        assert_ne!(
            (0..4).map(|_| r1.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| r2.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn mean_of_uniform_is_half() {
        let mut r = Pcg64::seed(2024);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn bits_are_balanced() {
        let mut r = Pcg64::seed(77);
        let mut ones = 0u64;
        let n = 10_000;
        for _ in 0..n {
            ones += r.next_u64().count_ones() as u64;
        }
        let frac = ones as f64 / (n as f64 * 64.0);
        assert!((frac - 0.5).abs() < 0.01, "frac={frac}");
    }
}
