//! SplitMix64 — seed expander (Steele, Lea, Flood 2014).
//!
//! Used only to derive well-mixed state/stream constants for [`Pcg64`]
//! from a small user seed; never used as the experiment generator itself.

use super::Rng;

/// The SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // Reference values for seed 1234567 from the public-domain C impl.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism across constructions.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn zero_seed_is_fine() {
        let mut sm = SplitMix64::new(0);
        assert_ne!(sm.next_u64(), 0);
    }
}
