//! A compiled artifact with a typed execute API.

use super::{ArtifactInfo, DType, RuntimeError};

/// Host-side tensor argument.
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    /// f32 data.
    F32(&'a [f32]),
    /// i32 data.
    I32(&'a [i32]),
}

impl Arg<'_> {
    fn len(&self) -> usize {
        match self {
            Arg::F32(s) => s.len(),
            Arg::I32(s) => s.len(),
        }
    }
    fn dtype(&self) -> DType {
        match self {
            Arg::F32(_) => DType::F32,
            Arg::I32(_) => DType::I32,
        }
    }
}

/// A compiled, ready-to-run artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    info: ArtifactInfo,
    client: xla::PjRtClient,
}

impl Executable {
    pub(super) fn new(
        exe: xla::PjRtLoadedExecutable,
        info: ArtifactInfo,
        client: xla::PjRtClient,
    ) -> Self {
        Self { exe, info, client }
    }

    /// Artifact metadata.
    pub fn info(&self) -> &ArtifactInfo {
        &self.info
    }

    fn check_args(&self, args: &[Arg<'_>]) -> Result<(), RuntimeError> {
        let sig = &self.info.inputs;
        if args.len() != sig.len() {
            return Err(RuntimeError::Signature {
                name: self.info.name.clone(),
                detail: format!("expected {} inputs, got {}", sig.len(), args.len()),
            });
        }
        for (i, (a, spec)) in args.iter().zip(sig).enumerate() {
            if a.len() != spec.elems() || a.dtype() != spec.dtype {
                return Err(RuntimeError::Signature {
                    name: self.info.name.clone(),
                    detail: format!(
                        "input {i}: expected {:?} x{} elems, got {:?} x{}",
                        spec.dtype,
                        spec.elems(),
                        a.dtype(),
                        a.len()
                    ),
                });
            }
        }
        Ok(())
    }

    fn literal_of(&self, a: &Arg<'_>, shape: &[usize]) -> Result<xla::Literal, RuntimeError> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match a {
            Arg::F32(s) => xla::Literal::vec1(s),
            Arg::I32(s) => xla::Literal::vec1(s),
        };
        // reshape() fails on rank-0; scalars keep the vec1 shape [1] and
        // XLA accepts it only if the artifact expects [1] — aot.py always
        // exports scalars as (1,1), so this path is for arrays.
        if dims.is_empty() {
            Ok(lit)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Execute with host slices; returns the output tuple as literals.
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<xla::Literal>, RuntimeError> {
        self.check_args(args)?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .zip(&self.info.inputs)
            .map(|(a, spec)| self.literal_of(a, &spec.shape))
            .collect::<Result<_, _>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute with device-resident buffers (the fast path — persistent
    /// inputs are uploaded once via [`Executable::upload_f32`]).
    pub fn run_b(
        &self,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>, RuntimeError> {
        let result = self.exe.execute_b(args)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Upload an f32 tensor to the device for reuse across executions.
    pub fn upload_f32(
        &self,
        data: &[f32],
        shape: &[usize],
    ) -> Result<xla::PjRtBuffer, RuntimeError> {
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(
        &self,
        data: &[i32],
        shape: &[usize],
    ) -> Result<xla::PjRtBuffer, RuntimeError> {
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    /// Convenience: run and copy output `idx` into `out` as f32.
    pub fn run_into(
        &self,
        args: &[Arg<'_>],
        idx: usize,
        out: &mut [f32],
    ) -> Result<(), RuntimeError> {
        let outputs = self.run(args)?;
        copy_f32(&outputs[idx], out, &self.info.name)
    }
}

/// Copy a literal's f32 payload into a slice (size-checked).
pub(crate) fn copy_f32(
    lit: &xla::Literal,
    out: &mut [f32],
    name: &str,
) -> Result<(), RuntimeError> {
    let n = lit.element_count();
    if n != out.len() {
        return Err(RuntimeError::Signature {
            name: name.to_string(),
            detail: format!("output has {n} elems, expected {}", out.len()),
        });
    }
    lit.copy_raw_to(out)?;
    Ok(())
}
