//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`).

use crate::config::json::Json;
use std::collections::BTreeMap;

/// Element dtype of an artifact tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed int.
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self, String> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(format!("unsupported dtype '{other}'")),
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: DType,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Manifest name (e.g. `linreg_grad_s40_d100`).
    pub name: String,
    /// HLO text file name within the artifact dir.
    pub file: String,
    /// Input signature.
    pub inputs: Vec<TensorSpec>,
    /// Output signature (the HLO returns these as one tuple).
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (`kind`, shape parameters, …).
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactInfo {
    /// The `kind` metadata field, if present.
    pub fn kind(&self) -> Option<&str> {
        self.meta.get("kind").and_then(|j| j.as_str())
    }

    /// Integer metadata field.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|j| j.as_usize())
    }
}

/// The parsed artifact registry.
#[derive(Debug, Clone)]
pub struct Manifest {
    entries: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self, String> {
        let root = Json::parse(text).map_err(|e| e.to_string())?;
        let version = root
            .get("version")
            .and_then(|v| v.as_usize())
            .ok_or("manifest missing integer 'version'")?;
        if version != 1 {
            return Err(format!("unsupported manifest version {version}"));
        }
        let entries = root
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or("manifest missing 'entries' array")?;
        let mut out = Vec::with_capacity(entries.len());
        for e in entries {
            out.push(Self::parse_entry(e)?);
        }
        Ok(Self { entries: out })
    }

    fn parse_entry(e: &Json) -> Result<ArtifactInfo, String> {
        let name = e
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or("entry missing 'name'")?
            .to_string();
        let file = e
            .get("file")
            .and_then(|v| v.as_str())
            .ok_or("entry missing 'file'")?
            .to_string();
        let specs = |key: &str| -> Result<Vec<TensorSpec>, String> {
            e.get(key)
                .and_then(|v| v.as_arr())
                .ok_or(format!("entry '{name}' missing '{key}'"))?
                .iter()
                .map(|t| {
                    let shape = t
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .ok_or("tensor missing 'shape'")?
                        .iter()
                        .map(|d| d.as_usize().ok_or("bad dim".to_string()))
                        .collect::<Result<Vec<usize>, String>>()?;
                    let dtype = DType::parse(
                        t.get("dtype")
                            .and_then(|v| v.as_str())
                            .ok_or("tensor missing 'dtype'")?,
                    )?;
                    Ok(TensorSpec { shape, dtype })
                })
                .collect()
        };
        let meta = match e.get("meta") {
            Some(Json::Obj(m)) => m.clone(),
            _ => BTreeMap::new(),
        };
        let inputs = specs("inputs")?;
        let outputs = specs("outputs")?;
        Ok(ArtifactInfo { name, file, inputs, outputs, meta })
    }

    /// Find an artifact by exact name.
    pub fn find(&self, name: &str) -> Option<&ArtifactInfo> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find the first artifact with a given `kind`.
    pub fn find_by_kind(&self, kind: &str) -> Option<&ArtifactInfo> {
        self.entries.iter().find(|e| e.kind() == Some(kind))
    }

    /// All artifact names.
    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactInfo] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "version": 1,
 "entries": [
  {"name": "linreg_grad_s40_d100", "file": "linreg_grad_s40_d100.hlo.txt",
   "inputs": [
     {"shape": [40, 100], "dtype": "float32"},
     {"shape": [40, 1], "dtype": "float32"},
     {"shape": [100, 1], "dtype": "float32"}],
   "outputs": [{"shape": [100, 1], "dtype": "float32"}],
   "meta": {"kind": "linreg_grad", "s": 40, "d": 100}},
  {"name": "tok", "file": "tok.hlo.txt",
   "inputs": [{"shape": [8, 65], "dtype": "int32"}],
   "outputs": [{"shape": [], "dtype": "float32"}],
   "meta": {"kind": "transformer_grad"}}
 ]
}"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries().len(), 2);
        let g = m.find("linreg_grad_s40_d100").unwrap();
        assert_eq!(g.inputs.len(), 3);
        assert_eq!(g.inputs[0].shape, vec![40, 100]);
        assert_eq!(g.inputs[0].elems(), 4000);
        assert_eq!(g.kind(), Some("linreg_grad"));
        assert_eq!(g.meta_usize("s"), Some(40));
        assert!(m.find_by_kind("transformer_grad").is_some());
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn scalar_output_shape() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let t = m.find("tok").unwrap();
        assert_eq!(t.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(t.outputs[0].elems(), 1);
        assert_eq!(t.inputs[0].dtype, DType::I32);
    }

    #[test]
    fn rejects_bad_versions_and_shapes() {
        assert!(Manifest::parse(r#"{"version": 2, "entries": []}"#).is_err());
        assert!(Manifest::parse(r#"{"entries": []}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
