//! PJRT runtime — loads and executes the AOT-compiled HLO artifacts.
//!
//! The bridge of the three-layer architecture: `python/compile/aot.py`
//! lowers the JAX/Pallas compute graphs to HLO *text* (the interchange
//! format that survives the jax≥0.5 ↔ xla_extension 0.5.1 proto-id
//! mismatch); this module parses them with
//! [`xla::HloModuleProto::from_text_file`], compiles them on the CPU PJRT
//! client, and exposes typed executables to the coordinator. Python never
//! runs here.
//!
//! Performance: inputs that don't change across iterations (the shard
//! matrices) are uploaded once as device-resident [`xla::PjRtBuffer`]s and
//! executions go through `execute_b`, so the per-iteration host↔device
//! traffic is only the model vector (see EXPERIMENTS.md §Perf).

mod executable;
mod manifest;
mod xla_backend;

pub use executable::{Arg, Executable};
pub(crate) use executable::copy_f32;
pub use manifest::{ArtifactInfo, DType, Manifest, TensorSpec};
pub use xla_backend::{XlaApplyUpdate, XlaBackend, XlaLossEval};

use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Runtime failures.
#[derive(Debug)]
pub enum RuntimeError {
    /// PJRT / XLA failure.
    Xla(xla::Error),
    /// Manifest parsing / lookup failure.
    Manifest(String),
    /// Caller passed inputs that don't match the artifact signature.
    Signature {
        /// Artifact name.
        name: String,
        /// What went wrong.
        detail: String,
    },
    /// I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(e) => write!(f, "xla: {e}"),
            RuntimeError::Manifest(msg) => write!(f, "manifest: {msg}"),
            RuntimeError::Signature { name, detail } => {
                write!(f, "signature mismatch for '{name}': {detail}")
            }
            RuntimeError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Xla(e) => Some(e),
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e)
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

/// Shared PJRT CPU client + artifact registry.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`, creates the
    /// PJRT CPU client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Arc<Self>, RuntimeError> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            RuntimeError::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = Manifest::parse(&text)
            .map_err(RuntimeError::Manifest)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Arc::new(Self { client, dir, manifest }))
    }

    /// Open the default artifact directory: `$ADASGD_ARTIFACTS` or
    /// `./artifacts`.
    pub fn open_default() -> Result<Arc<Self>, RuntimeError> {
        let dir = std::env::var("ADASGD_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    /// The PJRT client.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// The artifact registry.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load + compile an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Executable, RuntimeError> {
        let info = self
            .manifest
            .find(name)
            .ok_or_else(|| {
                RuntimeError::Manifest(format!(
                    "artifact '{name}' not in manifest (have: {})",
                    self.manifest.names().join(", ")
                ))
            })?
            .clone();
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable::new(exe, info, self.client.clone()))
    }

    /// Find the first artifact whose meta `kind` matches.
    pub fn load_kind(&self, kind: &str) -> Result<Executable, RuntimeError> {
        let name = self
            .manifest
            .find_by_kind(kind)
            .ok_or_else(|| {
                RuntimeError::Manifest(format!(
                    "no artifact of kind '{kind}' in manifest"
                ))
            })?
            .name
            .clone();
        self.load(&name)
    }
}
