//! [`GradBackend`] over the AOT linreg artifacts — the production path.
//!
//! Each shard's `X_i` and `y_i` are uploaded to the device once at
//! construction; per call only the model vector `w` crosses the host
//! boundary, and the executable runs on persistent buffers (`execute_b`).

use super::{Arg, Executable, Runtime, RuntimeError};
use crate::data::Shards;
use crate::grad::GradBackend;
use std::sync::Arc;

/// PJRT-backed partial-gradient backend (paper hot path through the
/// Pallas kernel artifact).
pub struct XlaBackend {
    grad_exe: Executable,
    /// Per-shard device-resident inputs (X_i, y_i).
    shard_bufs: Vec<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    /// Batched all-shards path: `linreg_grad_all` executable plus the
    /// stacked `(n,s,d)` / `(n,s,1)` device-resident inputs. Present when
    /// the artifact exists in the manifest (§Perf: one dispatch/iteration
    /// instead of k).
    batched: Option<(Executable, xla::PjRtBuffer, xla::PjRtBuffer)>,
    n: usize,
    d: usize,
    s: usize,
}

impl XlaBackend {
    /// Build from shards; requires the `linreg_grad_s{s}_d{d}` artifact to
    /// exist (shapes must match — HLO is shape-static).
    pub fn new(runtime: &Arc<Runtime>, shards: &Shards) -> Result<Self, RuntimeError> {
        let d = shards.x[0].cols();
        let s = shards.s;
        let name = format!("linreg_grad_s{s}_d{d}");
        let grad_exe = runtime.load(&name)?;
        for (i, x) in shards.x.iter().enumerate() {
            if x.rows() != s {
                return Err(RuntimeError::Signature {
                    name: name.clone(),
                    detail: format!(
                        "shard {i} has {} rows but artifact expects s={s} \
                         (uneven sharding requires per-size artifacts)",
                        x.rows()
                    ),
                });
            }
        }
        let mut shard_bufs = Vec::with_capacity(shards.n());
        for i in 0..shards.n() {
            let xb = grad_exe.upload_f32(shards.x[i].as_slice(), &[s, d])?;
            let yb = grad_exe.upload_f32(&shards.y[i], &[s, 1])?;
            shard_bufs.push((xb, yb));
        }
        let n = shards.n();
        // Optional batched artifact: stack shards and pin on device.
        let batched = match runtime
            .load(&format!("linreg_grad_all_n{n}_s{s}_d{d}"))
        {
            Err(_) => None,
            Ok(exe) => {
                let mut x_all = Vec::with_capacity(n * s * d);
                let mut y_all = Vec::with_capacity(n * s);
                for i in 0..n {
                    x_all.extend_from_slice(shards.x[i].as_slice());
                    y_all.extend_from_slice(&shards.y[i]);
                }
                let xb = exe.upload_f32(&x_all, &[n, s, d])?;
                let yb = exe.upload_f32(&y_all, &[n, s, 1])?;
                Some((exe, xb, yb))
            }
        };
        Ok(Self { grad_exe, shard_bufs, batched, n, d, s })
    }

    /// Rows per shard.
    pub fn s(&self) -> usize {
        self.s
    }

    /// Fallible partial gradient (the trait wrapper panics on runtime
    /// errors; prefer this in library code that wants to handle them).
    pub fn try_partial_grad(
        &mut self,
        shard: usize,
        w: &[f32],
        out: &mut [f32],
    ) -> Result<(), RuntimeError> {
        let (xb, yb) = &self.shard_bufs[shard];
        let wb = self.grad_exe.upload_f32(w, &[self.d, 1])?;
        let outputs = self.grad_exe.run_b(&[xb, yb, &wb])?;
        super::executable::copy_f32(&outputs[0], out, "linreg_grad")
    }
}

impl GradBackend for XlaBackend {
    fn partial_grad(&mut self, shard: usize, w: &[f32], out: &mut [f32]) {
        self.try_partial_grad(shard, w, out)
            .expect("PJRT partial-gradient execution failed");
    }

    fn supports_all_grads(&self) -> bool {
        self.batched.is_some()
    }

    fn all_grads(&mut self, w: &[f32], out: &mut [f32]) -> bool {
        let Some((exe, xb, yb)) = &self.batched else { return false };
        debug_assert_eq!(out.len(), self.n * self.d);
        let wb = exe
            .upload_f32(w, &[self.d, 1])
            .expect("PJRT upload failed");
        let outputs =
            exe.run_b(&[xb, yb, &wb]).expect("PJRT batched grad failed");
        super::executable::copy_f32(&outputs[0], out, "linreg_grad_all")
            .expect("PJRT batched grad output");
        true
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn n_shards(&self) -> usize {
        self.shard_bufs.len()
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}

/// Loss evaluator over the full dataset via the `linreg_loss` artifact.
pub struct XlaLossEval {
    exe: Executable,
    x_buf: xla::PjRtBuffer,
    y_buf: xla::PjRtBuffer,
    d: usize,
}

impl XlaLossEval {
    /// Load `linreg_loss_m{m}_d{d}` and pin the dataset on device.
    pub fn new(
        runtime: &Arc<Runtime>,
        x: &crate::linalg::Matrix,
        y: &[f32],
    ) -> Result<Self, RuntimeError> {
        let (m, d) = (x.rows(), x.cols());
        let exe = runtime.load(&format!("linreg_loss_m{m}_d{d}"))?;
        let x_buf = exe.upload_f32(x.as_slice(), &[m, d])?;
        let y_buf = exe.upload_f32(y, &[m, 1])?;
        Ok(Self { exe, x_buf, y_buf, d })
    }

    /// `F(w)`.
    pub fn loss(&self, w: &[f32]) -> Result<f64, RuntimeError> {
        let wb = self.exe.upload_f32(w, &[self.d, 1])?;
        let outputs = self.exe.run_b(&[&self.x_buf, &self.y_buf, &wb])?;
        let mut out = [0.0f32];
        super::executable::copy_f32(&outputs[0], &mut out, "linreg_loss")?;
        Ok(out[0] as f64)
    }
}

/// Fused fastest-k apply via the `apply_update` artifact: the masked
/// gradient stack lives host-side; rows `k..n` must be zeroed by the
/// caller; `step_scale = η/k`.
pub struct XlaApplyUpdate {
    exe: Executable,
    n: usize,
    d: usize,
}

impl XlaApplyUpdate {
    /// Load `apply_update_n{n}_d{d}`.
    pub fn new(runtime: &Arc<Runtime>, n: usize, d: usize) -> Result<Self, RuntimeError> {
        let exe = runtime.load(&format!("apply_update_n{n}_d{d}"))?;
        Ok(Self { exe, n, d })
    }

    /// `w ← w − step_scale · Σ_rows(G)` (in place on the host vector).
    pub fn apply(
        &self,
        w: &mut [f32],
        g_stack: &[f32],
        step_scale: f32,
    ) -> Result<(), RuntimeError> {
        debug_assert_eq!(g_stack.len(), self.n * self.d);
        let scale = [step_scale];
        let outputs = self.exe.run(&[
            Arg::F32(w),
            Arg::F32(g_stack),
            Arg::F32(&scale),
        ])?;
        super::executable::copy_f32(&outputs[0], w, "apply_update")
    }
}
