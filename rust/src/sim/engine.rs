//! Binary-heap event queue with a stable tie-break.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event carrying a payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event<T> {
    /// Virtual firing time.
    pub time: f64,
    /// Monotone sequence number: FIFO among equal times.
    seq: u64,
    /// Payload.
    pub payload: T,
}

impl<T> Eq for Event<T> where T: PartialEq {}

impl<T: PartialEq> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T: PartialEq> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour on BinaryHeap (a max-heap).
        // total_cmp instead of partial_cmp: a NaN time would otherwise
        // silently compare Equal and corrupt the heap order. NaN can't
        // get in (schedule_at asserts finiteness) but the ordering must
        // not be the line that depends on it.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap of timed events over a virtual clock.
#[derive(Debug)]
pub struct EventQueue<T: PartialEq> {
    heap: BinaryHeap<Event<T>>,
    now: f64,
    seq: u64,
}

impl<T: PartialEq> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: PartialEq> EventQueue<T> {
    /// Empty queue at time 0.
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at absolute time `at` (must be finite and not
    /// in the past). The finiteness check runs first: a NaN `at` must
    /// report "not finite", not the misleading "in the past" (NaN fails
    /// every comparison).
    pub fn schedule_at(&mut self, at: f64, payload: T) {
        assert!(at.is_finite(), "event time must be finite (got {at})");
        assert!(at >= self.now, "cannot schedule into the past");
        self.heap.push(Event { time: at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` after a relative `delay >= 0` (finite; NaN and
    /// +inf are rejected).
    pub fn schedule_in(&mut self, delay: f64, payload: T) {
        assert!(delay.is_finite(), "delay must be finite (got {delay})");
        assert!(delay >= 0.0, "delay must be non-negative");
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<Event<T>> {
        let ev = self.heap.pop()?;
        self.now = ev.time;
        Some(ev)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.now(), 1.0);
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn fifo_among_ties() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        assert_eq!(q.pop().unwrap().payload, 1);
        assert_eq!(q.pop().unwrap().payload, 2);
        assert_eq!(q.pop().unwrap().payload, 3);
    }

    #[test]
    fn relative_scheduling_tracks_now() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, "x");
        q.pop();
        q.schedule_in(2.0, "y");
        let e = q.pop().unwrap();
        assert_eq!(e.time, 7.0);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, ());
        q.pop();
        q.schedule_at(1.0, ());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time_with_the_right_message() {
        // Regression: NaN used to fall into the `>= now` assert and report
        // "cannot schedule into the past".
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_infinite_delay() {
        let mut q = EventQueue::new();
        q.schedule_in(f64::INFINITY, ());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_delay() {
        let mut q = EventQueue::new();
        q.schedule_in(f64::NAN, ());
    }

    #[test]
    fn fifo_ties_survive_interleaved_pops_and_pushes() {
        // Ties at the same timestamp must pop in insertion order even
        // when the heap has seen pops and later events in between.
        let mut q = EventQueue::new();
        q.schedule_at(1.0, "a1");
        q.schedule_at(0.5, "early");
        q.schedule_at(1.0, "a2");
        assert_eq!(q.pop().unwrap().payload, "early");
        q.schedule_at(1.0, "a3");
        q.schedule_at(2.0, "late");
        assert_eq!(q.pop().unwrap().payload, "a1");
        assert_eq!(q.pop().unwrap().payload, "a2");
        assert_eq!(q.pop().unwrap().payload, "a3");
        assert_eq!(q.pop().unwrap().payload, "late");
        assert!(q.pop().is_none());
    }
}
