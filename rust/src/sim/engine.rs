//! Binary-heap event queue with a stable tie-break.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event carrying a payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event<T> {
    /// Virtual firing time.
    pub time: f64,
    /// Monotone sequence number: FIFO among equal times.
    seq: u64,
    /// Payload.
    pub payload: T,
}

impl<T> Eq for Event<T> where T: PartialEq {}

impl<T: PartialEq> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T: PartialEq> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour on BinaryHeap (a max-heap).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap of timed events over a virtual clock.
#[derive(Debug)]
pub struct EventQueue<T: PartialEq> {
    heap: BinaryHeap<Event<T>>,
    now: f64,
    seq: u64,
}

impl<T: PartialEq> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: PartialEq> EventQueue<T> {
    /// Empty queue at time 0.
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at absolute time `at` (must not be in the past).
    pub fn schedule_at(&mut self, at: f64, payload: T) {
        assert!(at >= self.now, "cannot schedule into the past");
        assert!(at.is_finite(), "event time must be finite");
        self.heap.push(Event { time: at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` after a relative `delay >= 0`.
    pub fn schedule_in(&mut self, delay: f64, payload: T) {
        assert!(delay >= 0.0, "delay must be non-negative");
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<Event<T>> {
        let ev = self.heap.pop()?;
        self.now = ev.time;
        Some(ev)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.now(), 1.0);
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn fifo_among_ties() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        assert_eq!(q.pop().unwrap().payload, 1);
        assert_eq!(q.pop().unwrap().payload, 2);
        assert_eq!(q.pop().unwrap().payload, 3);
    }

    #[test]
    fn relative_scheduling_tracks_now() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, "x");
        q.pop();
        q.schedule_in(2.0, "y");
        let e = q.pop().unwrap();
        assert_eq!(e.time, 7.0);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, ());
        q.pop();
        q.schedule_at(1.0, ());
    }
}
