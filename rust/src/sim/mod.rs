//! Discrete-event simulation engine.
//!
//! A tiny but general event queue over a virtual clock: the asynchronous
//! SGD baseline and the ablation harnesses schedule worker-completion
//! events on it. (The synchronous fastest-k loop doesn't need a queue —
//! its iteration time is a single order statistic — so it advances the
//! clock directly.)

mod engine;

pub use engine::{Event, EventQueue};
