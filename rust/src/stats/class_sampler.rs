//! K-way merge of per-class order-statistic streams: the first-k
//! arrivals of a *class-heterogeneous* fleet in O(k · classes).
//!
//! The plain [`OrderStatSampler`] needs all n delays i.i.d. Real fleets
//! are class-heterogeneous — a slow rack, a throttled uplink tier — but
//! still i.i.d. *within* each class, and the priced uplink adds only a
//! per-worker **constant** (latency + bytes/bandwidth for the round's
//! fixed, data-independent message size). That structure is enough:
//!
//! * each class's arrival stream (its own ascending order statistics,
//!   plus the class's constant uplink shift) is sampled lazily with the
//!   existing O(k) machinery;
//! * a k-way merge over the per-class stream heads yields the global
//!   ascending first-k prefix.
//!
//! **Why the merged prefix has the exact law of the exhaustive order
//! statistics:** each head is the minimum *remaining* arrival of its
//! class (the stream is ascending and the shift is a constant, which
//! shifts every class order statistic by the same amount and so
//! preserves order). The minimum over class heads is therefore the
//! minimum over all remaining arrivals in the fleet — the next global
//! order statistic. Induction over k pops gives the full prefix.
//! Sharing one rng across classes with a data-dependent consumption
//! order is also exact: every draw is an independent uniform, so the
//! conditional law of each class's next spacing given everything drawn
//! so far is unchanged.
//!
//! A single-class `ClassOrderSampler` consumes the rng draw-for-draw
//! identically to `OrderStatSampler::sample_first_k` (pinned in the
//! tests below), so the homogeneous fastpath trajectory is preserved
//! bit for bit when expressed through this type.

use super::order_sampler::{OrderStatSampler, StreamState};
use crate::rng::Rng;

/// O(k · classes) sampler of the merged ascending first-k arrival times
/// of a fleet partitioned into homogeneous delay/link classes.
///
/// Each class pairs an [`OrderStatSampler`] sized to the class's member
/// count with a constant response-time shift (its uplink constant; 0.0
/// for free links). Scratch buffers are reused across rounds, so
/// steady-state rounds are allocation-free.
pub struct ClassOrderSampler {
    /// Per-class order-statistic samplers (sized to the class).
    samplers: Vec<OrderStatSampler>,
    /// Per-class constant arrival shift (uplink constant).
    shifts: Vec<f64>,
    /// Per-class resumable stream positions (reset each round).
    states: Vec<StreamState>,
    /// Current head (next merged candidate) per class.
    heads: Vec<f64>,
    /// Whether the class still has a live head to merge.
    alive: Vec<bool>,
    /// Total fleet size (sum of class sizes).
    n: usize,
}

impl ClassOrderSampler {
    /// Build from `(sampler, shift)` classes in a fixed class order —
    /// class indices reported by [`Self::sample_first_k`] refer to this
    /// order. Shifts must be finite and non-negative.
    pub fn new(classes: Vec<(OrderStatSampler, f64)>) -> Self {
        assert!(!classes.is_empty(), "need at least one class");
        let mut samplers = Vec::with_capacity(classes.len());
        let mut shifts = Vec::with_capacity(classes.len());
        for (s, shift) in classes {
            assert!(
                shift.is_finite() && shift >= 0.0,
                "class shift must be finite and >= 0, got {shift}"
            );
            samplers.push(s);
            shifts.push(shift);
        }
        let n = samplers.iter().map(|s| s.n()).sum();
        let c = samplers.len();
        Self {
            samplers,
            shifts,
            states: vec![StreamState::default(); c],
            heads: vec![0.0; c],
            alive: vec![false; c],
            n,
        }
    }

    /// A single free-link class — the homogeneous i.i.d. case.
    pub fn single(sampler: OrderStatSampler) -> Self {
        Self::new(vec![(sampler, 0.0)])
    }

    /// Total fleet size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of classes.
    pub fn classes(&self) -> usize {
        self.samplers.len()
    }

    /// Member count of class `c`.
    pub fn class_size(&self, c: usize) -> usize {
        self.samplers[c].n()
    }

    /// Draw the merged ascending first-k arrival times into `arrivals`
    /// and, per arrival, the index of the class it came from into
    /// `class_ids` (both cleared first). O(k · classes) time, at most
    /// `k + classes − 1` rng draws; with one class, exactly k draws in
    /// [`OrderStatSampler::sample_first_k`] order. Panics unless
    /// `1 <= k <= n`.
    pub fn sample_first_k<R: Rng + ?Sized>(
        &mut self,
        k: usize,
        arrivals: &mut Vec<f64>,
        class_ids: &mut Vec<u32>,
        rng: &mut R,
    ) {
        assert!(k >= 1 && k <= self.n, "k must be in 1..=n");
        arrivals.clear();
        class_ids.clear();
        // Fresh streams; one head per class, drawn in class order so
        // rng consumption is deterministic given (k, class layout).
        for c in 0..self.samplers.len() {
            self.states[c] = self.samplers[c].stream_start();
            self.heads[c] =
                self.samplers[c].stream_next(&mut self.states[c], rng)
                    + self.shifts[c];
            self.alive[c] = true;
        }
        // Remaining undrawn members per class live in the stream states;
        // track them locally to know when a head cannot be refilled.
        for pop in 0..k {
            // Argmin over live heads; ties go to the lowest class index
            // (strict `<` keeps the first minimum found).
            let mut best = usize::MAX;
            for c in 0..self.heads.len() {
                if self.alive[c]
                    && (best == usize::MAX || self.heads[c] < self.heads[best])
                {
                    best = c;
                }
            }
            debug_assert!(best != usize::MAX, "ran out of live heads");
            arrivals.push(self.heads[best]);
            class_ids.push(best as u32);
            // Refill the popped head only while more pops remain — with
            // one class this keeps the total at exactly k draws, the
            // draw-for-draw pin against `OrderStatSampler`.
            if pop + 1 < k {
                if self.states[best].taken() < self.samplers[best].n() {
                    self.heads[best] = self.samplers[best]
                        .stream_next(&mut self.states[best], rng)
                        + self.shifts[best];
                } else {
                    self.alive[best] = false;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn single_class_reproduces_order_stat_sampler_draw_for_draw() {
        // The homogeneous case is the PR-8 fastpath: same draws, same
        // bits, same rng stream position afterwards.
        let plain = OrderStatSampler::exponential(50, 1.3);
        let mut merged = ClassOrderSampler::single(
            OrderStatSampler::exponential(50, 1.3),
        );
        let mut a = Pcg64::seed(5);
        let mut b = Pcg64::seed(5);
        let (mut want, mut got, mut cls) = (Vec::new(), Vec::new(), Vec::new());
        for k in [1usize, 7, 50] {
            plain.sample_first_k(k, &mut want, &mut a);
            merged.sample_first_k(k, &mut got, &mut cls, &mut b);
            assert_eq!(got.len(), k);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
            assert!(cls.iter().all(|&c| c == 0));
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn merged_prefix_is_ascending_and_spans_classes() {
        // Two very different classes: a fast majority and a slow tail
        // with a large uplink shift. The merge must stay ascending and
        // the early prefix should be dominated by the fast class.
        let mut s = ClassOrderSampler::new(vec![
            (OrderStatSampler::exponential(30, 2.0), 0.0),
            (OrderStatSampler::exponential(10, 0.2), 1.0),
        ]);
        assert_eq!(s.n(), 40);
        assert_eq!(s.classes(), 2);
        assert_eq!(s.class_size(0), 30);
        assert_eq!(s.class_size(1), 10);
        let mut rng = Pcg64::seed(3);
        let (mut arr, mut cls) = (Vec::new(), Vec::new());
        let mut slow_seen = 0usize;
        for _ in 0..200 {
            s.sample_first_k(40, &mut arr, &mut cls, &mut rng);
            assert_eq!(arr.len(), 40);
            assert!(arr.windows(2).all(|w| w[0] <= w[1]), "{arr:?}");
            // Exactly the class populations are consumed.
            assert_eq!(cls.iter().filter(|&&c| c == 0).count(), 30);
            assert_eq!(cls.iter().filter(|&&c| c == 1).count(), 10);
            // The slow class's shift floors its arrivals at 1.0.
            for (a, &c) in arr.iter().zip(&cls) {
                if c == 1 {
                    assert!(*a >= 1.0);
                    slow_seen += 1;
                }
            }
        }
        assert_eq!(slow_seen, 200 * 10);
    }

    #[test]
    fn merged_law_matches_exhaustive_heterogeneous_sampling() {
        // Monte-Carlo: merged k-th arrival vs exhaustively drawing every
        // worker's shifted delay and sorting. 8 fast Exp(2) workers with
        // shift 0.1 + 4 slow Exp(0.5) workers with shift 0.5.
        let (nf, ns, k) = (8usize, 4usize, 6usize);
        let mut merged = ClassOrderSampler::new(vec![
            (OrderStatSampler::exponential(nf, 2.0), 0.1),
            (OrderStatSampler::exponential(ns, 0.5), 0.5),
        ]);
        let rounds = 60_000;
        let mut fast_rng = Pcg64::seed_stream(11, 1);
        let mut ex_rng = Pcg64::seed_stream(11, 2);
        let (mut arr, mut cls) = (Vec::new(), Vec::new());
        let (mut m_fast, mut m_ex) = (0.0f64, 0.0f64);
        let mut buf = Vec::with_capacity(nf + ns);
        for _ in 0..rounds {
            merged.sample_first_k(k, &mut arr, &mut cls, &mut fast_rng);
            m_fast += arr[k - 1];
            buf.clear();
            for _ in 0..nf {
                buf.push(0.1 - ex_rng.next_f64_open().ln() / 2.0);
            }
            for _ in 0..ns {
                buf.push(0.5 - ex_rng.next_f64_open().ln() / 0.5);
            }
            buf.sort_unstable_by(|a, b| a.total_cmp(b));
            m_ex += buf[k - 1];
        }
        let (m_fast, m_ex) =
            (m_fast / rounds as f64, m_ex / rounds as f64);
        assert!(
            (m_fast - m_ex).abs() < 0.01,
            "merged mean {m_fast} vs exhaustive {m_ex}"
        );
    }

    #[test]
    fn ties_resolve_to_the_lowest_class_index() {
        // Two deterministic-ish classes cannot produce exact float ties
        // from the rng, so pin the argmin rule structurally: identical
        // class parameters and shifts make head distributions equal, and
        // the strict `<` means equal heads pop class 0 first. Verified
        // indirectly: single-member classes with equal huge shifts —
        // the shift dominates, heads are near-equal, the merge must
        // still consume every member exactly once in ascending order.
        let mut s = ClassOrderSampler::new(vec![
            (OrderStatSampler::exponential(1, 1.0), 10.0),
            (OrderStatSampler::exponential(1, 1.0), 10.0),
        ]);
        let mut rng = Pcg64::seed(9);
        let (mut arr, mut cls) = (Vec::new(), Vec::new());
        s.sample_first_k(2, &mut arr, &mut cls, &mut rng);
        assert!(arr[0] <= arr[1]);
        let mut seen = cls.clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "k must be in 1..=n")]
    fn rejects_k_beyond_fleet_size() {
        let mut s = ClassOrderSampler::new(vec![
            (OrderStatSampler::exponential(2, 1.0), 0.0),
            (OrderStatSampler::exponential(2, 1.0), 0.0),
        ]);
        s.sample_first_k(
            5,
            &mut Vec::new(),
            &mut Vec::new(),
            &mut Pcg64::seed(0),
        );
    }

    #[test]
    #[should_panic(expected = "finite and >= 0")]
    fn rejects_negative_shift() {
        let _ = ClassOrderSampler::new(vec![(
            OrderStatSampler::exponential(2, 1.0),
            -0.5,
        )]);
    }
}
