//! Harmonic numbers `H_n = Σ 1/i` and generalized `H_n^(2) = Σ 1/i²`.
//!
//! For iid `exp(λ)` response times the k-th order statistic has
//! `E[X_(k)] = (H_n − H_{n−k})/λ` and
//! `Var[X_(k)] = (H_n^(2) − H_{n−k}^(2))/λ²` (Rényi representation) —
//! exactly the quantities in the paper's Example 1 and Lemma 1.

/// `H_n = Σ_{i=1..n} 1/i`, with `H_0 = 0`.
pub fn harmonic(n: usize) -> f64 {
    // Direct summation is exact enough for any n we see (n ≤ 10⁶);
    // summed smallest-first for accuracy.
    (1..=n).rev().map(|i| 1.0 / i as f64).sum()
}

/// `H_n^(2) = Σ_{i=1..n} 1/i²`, with `H_0^(2) = 0`.
pub fn harmonic_sq(n: usize) -> f64 {
    (1..=n).rev().map(|i| 1.0 / (i as f64 * i as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values() {
        assert_eq!(harmonic(0), 0.0);
        assert_eq!(harmonic(1), 1.0);
        assert!((harmonic(2) - 1.5).abs() < 1e-12);
        assert!((harmonic(5) - 137.0 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn asymptotics() {
        // H_n ~ ln n + gamma
        let n = 1_000_000;
        let gamma = 0.5772156649015329;
        assert!((harmonic(n) - ((n as f64).ln() + gamma)).abs() < 1e-5);
    }

    #[test]
    fn harmonic_sq_converges_to_pi2_over_6() {
        let want = std::f64::consts::PI * std::f64::consts::PI / 6.0;
        assert!((harmonic_sq(1_000_000) - want).abs() < 1e-5);
    }

    #[test]
    fn monotone() {
        for n in 1..100 {
            assert!(harmonic(n) > harmonic(n - 1));
            assert!(harmonic_sq(n) > harmonic_sq(n - 1));
        }
    }
}
