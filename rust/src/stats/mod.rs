//! Statistics substrate: harmonic numbers, order statistics, running
//! moments, quantiles.
//!
//! The paper's analysis lives on the k-th order statistic `X_(k)` of the
//! n worker response times: the per-iteration wall-clock of fastest-k SGD.
//! [`order_stats`] provides `μ_k = E[X_(k)]` and `σ_k² = Var[X_(k)]`
//! analytically for the exponential model (via harmonic sums — the form
//! used in the paper's Example 1) and by Monte-Carlo for arbitrary
//! [`DelayModel`](crate::straggler::DelayModel)s. [`OrderStatSampler`]
//! *draws* the ascending first-k arrivals of n i.i.d. delays in O(k) —
//! the engine fastpath's statistical core — and [`ClassOrderSampler`]
//! k-way-merges per-class streams to cover class-heterogeneous fleets
//! (slow worker groups, per-class uplink constants) in O(k · classes).

mod class_sampler;
mod harmonic;
mod order_sampler;
mod order_stats;
mod running;

pub use class_sampler::ClassOrderSampler;
pub use harmonic::{harmonic, harmonic_sq};
pub use order_sampler::OrderStatSampler;
pub use order_stats::{
    exponential_order_mean, exponential_order_var, OrderStats,
};
pub use running::{quantile, RunningStats};
