//! O(k) sampling of the first k order statistics of n i.i.d. delays.
//!
//! The engine's exhaustive sync round draws all n worker delays and
//! quickselects the k fastest — O(n) work and O(n) rng draws per round,
//! capping experiments at n in the thousands. For i.i.d. delay models the
//! paper's round time depends on the delays only through the k-th order
//! statistic `X_(k)`, and that statistic (with the whole ascending prefix
//! `X_(1..k)`) can be sampled *directly* in O(k):
//!
//! * **Exponential / shifted exponential** — the Rényi representation:
//!   the normalized spacings of exponential order statistics are i.i.d.
//!   exponentials, `X_(i+1) − X_(i) ~ Exp((n−i)·λ)`, so a cumulative sum
//!   of k spacing draws yields `X_(1..k)` exactly.
//! * **Any i.i.d. model with an inverse CDF** (Pareto, Weibull here) —
//!   conditional uniform order statistics: the survival value
//!   `S_(i) = 1 − U_(i)` of the i-th smallest of n uniforms satisfies
//!   `S_(1) = V₁^{1/n}`, `S_(i+1) = S_(i) · V_{i+1}^{1/(n−i)}` with
//!   `V_j` i.i.d. uniform, and `X_(i) = S⁻¹(S_(i))` via the model's
//!   [`quantile_tail`](crate::rng::Exponential::quantile_tail). Working
//!   in the log-tail domain avoids the `1 − p` cancellation entirely.
//!
//! Both forms are *distributionally* exact — same law as sorting n
//! draws — but not bitwise equal to the exhaustive path (different draw
//! count and order), which is why the engine's fastpath gather is opt-in
//! (see `engine/fastpath.rs` and the §Perf notes in `lib.rs`).

use super::order_stats::exponential_order_mean;
use crate::rng::{Pareto, Rng, Weibull};

/// Which analytic family the sampler draws from.
#[derive(Debug, Clone)]
enum Kind {
    /// `shift + Exp(lambda)` via Rényi spacings (`shift = 0` is the
    /// paper's §V exponential).
    ShiftedExp {
        /// Deterministic offset added to every arrival.
        shift: f64,
        /// Exponential rate.
        lambda: f64,
    },
    /// Pareto(xm, alpha) via conditional uniforms + inverse CDF.
    Pareto(Pareto),
    /// Weibull(lambda, k) via conditional uniforms + inverse CDF.
    Weibull(Weibull),
}

/// O(k) sampler of the ascending first-k arrival times among n i.i.d.
/// worker delays.
#[derive(Debug, Clone)]
pub struct OrderStatSampler {
    n: usize,
    kind: Kind,
}

impl OrderStatSampler {
    /// Exponential delays with rate `lambda` (the paper's §V model).
    pub fn exponential(n: usize, lambda: f64) -> Self {
        Self::shifted_exponential(n, 0.0, lambda)
    }

    /// Shifted-exponential delays: `shift + Exp(lambda)`.
    pub fn shifted_exponential(n: usize, shift: f64, lambda: f64) -> Self {
        assert!(n >= 1, "need at least one worker");
        assert!(lambda > 0.0, "lambda must be > 0");
        assert!(shift >= 0.0, "shift must be >= 0");
        Self { n, kind: Kind::ShiftedExp { shift, lambda } }
    }

    /// Pareto(xm, alpha) delays.
    pub fn pareto(n: usize, xm: f64, alpha: f64) -> Self {
        assert!(n >= 1, "need at least one worker");
        Self { n, kind: Kind::Pareto(Pareto::new(xm, alpha)) }
    }

    /// Weibull(lambda, k) delays.
    pub fn weibull(n: usize, lambda: f64, k: f64) -> Self {
        assert!(n >= 1, "need at least one worker");
        Self { n, kind: Kind::Weibull(Weibull::new(lambda, k)) }
    }

    /// Workers the sampler is sized for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Human-readable family label for reports.
    pub fn name(&self) -> String {
        match &self.kind {
            Kind::ShiftedExp { shift, lambda } if *shift == 0.0 => {
                format!("exp(lambda={lambda})")
            }
            Kind::ShiftedExp { shift, lambda } => {
                format!("shifted-exp(shift={shift}, lambda={lambda})")
            }
            Kind::Pareto(p) => {
                format!("pareto(xm={}, alpha={})", p.xm, p.alpha)
            }
            Kind::Weibull(w) => {
                format!("weibull(lambda={}, k={})", w.lambda, w.k)
            }
        }
    }

    /// Draw the ascending arrival times `X_(1) <= … <= X_(k)` of the k
    /// fastest of n i.i.d. delays into `out` (cleared first), using
    /// exactly k rng draws. Panics unless `1 <= k <= n`.
    pub fn sample_first_k<R: Rng + ?Sized>(
        &self,
        k: usize,
        out: &mut Vec<f64>,
        rng: &mut R,
    ) {
        assert!(k >= 1 && k <= self.n, "k must be in 1..=n");
        out.clear();
        let mut st = self.stream_start();
        for _ in 0..k {
            out.push(self.stream_next(&mut st, rng));
        }
    }

    /// Begin a fresh ascending arrival stream for one round (the
    /// resumable form of [`Self::sample_first_k`]; the class-merge
    /// sampler interleaves several of these).
    pub(crate) fn stream_start(&self) -> StreamState {
        StreamState::default()
    }

    /// Draw the next ascending arrival of the stream `st` — exactly one
    /// rng draw per call, and calling it k times from a fresh state
    /// reproduces `sample_first_k(k, ..)` draw for draw, bit for bit.
    /// Panics once all n arrivals have been drawn.
    pub(crate) fn stream_next<R: Rng + ?Sized>(
        &self,
        st: &mut StreamState,
        rng: &mut R,
    ) -> f64 {
        assert!(st.taken < self.n, "order-stat stream exhausted");
        let i = st.taken;
        st.taken += 1;
        match &self.kind {
            Kind::ShiftedExp { shift, lambda } => {
                // Rényi spacings: each gap is Exp((n−i)·λ), drawn with
                // the same `-ln U / rate` form as the exhaustive model.
                st.cum += -rng.next_f64_open().ln()
                    / ((self.n - i) as f64 * lambda);
                shift + st.cum
            }
            // Conditional uniforms in log-survival space; see the
            // module docs. ln S_(i) = Σ_{j<=i} ln(V_j)/(n−j+1).
            Kind::Pareto(p) => {
                st.ln_tail +=
                    rng.next_f64_open().ln() / ((self.n - i) as f64);
                p.quantile_tail(st.ln_tail.exp())
            }
            Kind::Weibull(w) => {
                st.ln_tail +=
                    rng.next_f64_open().ln() / ((self.n - i) as f64);
                w.quantile_tail(st.ln_tail.exp())
            }
        }
    }

    /// Closed-form `E[X_(k)]` where one exists: the (shifted-)exponential
    /// family's `shift + (H_n − H_{n−k})/λ` (the quantity `theory`'s
    /// error bound is built on). `None` for Pareto/Weibull.
    pub fn expected_kth(&self, k: usize) -> Option<f64> {
        match &self.kind {
            Kind::ShiftedExp { shift, lambda } => {
                Some(shift + exponential_order_mean(self.n, k, *lambda))
            }
            _ => None,
        }
    }
}

/// Resumable position of one ascending arrival stream: how many arrivals
/// were drawn plus the family-specific running term (the Rényi cumulative
/// spacing sum, or the conditional-uniform log-survival walk). Plain data
/// — holding one per class lets the class-merge sampler interleave
/// streams without borrowing the samplers themselves.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct StreamState {
    /// Arrivals drawn so far (the order-statistic rank reached).
    taken: usize,
    /// ShiftedExp: cumulative spacing sum `Σ gaps`.
    cum: f64,
    /// Pareto/Weibull: running `ln S_(i)` of the uniform order walk.
    ln_tail: f64,
}

impl StreamState {
    /// Arrivals drawn from this stream so far (exhausted at the
    /// sampler's n).
    pub(crate) fn taken(&self) -> usize {
        self.taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Pcg64};
    use crate::stats::exponential_order_var;

    fn mean_var(samples: &[f64]) -> (f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n;
        (mean, var)
    }

    #[test]
    fn arrivals_are_ascending_and_use_k_draws() {
        let s = OrderStatSampler::exponential(100, 1.3);
        let mut rng = Pcg64::seed(1);
        let mut out = Vec::new();
        for _ in 0..200 {
            s.sample_first_k(7, &mut out, &mut rng);
            assert_eq!(out.len(), 7);
            for w in out.windows(2) {
                assert!(w[0] <= w[1], "arrivals must ascend: {out:?}");
            }
            assert!(out[0] > 0.0);
        }
        // Draw-count contract: k draws exactly, so two samplers sharing
        // a stream stay aligned.
        let mut a = Pcg64::seed(9);
        let mut b = Pcg64::seed(9);
        s.sample_first_k(5, &mut out, &mut a);
        for _ in 0..5 {
            b.next_f64_open();
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn renyi_kth_matches_closed_form_moments() {
        let (n, k, lambda) = (40, 10, 2.0);
        let s = OrderStatSampler::exponential(n, lambda);
        let mut rng = Pcg64::seed(2);
        let mut out = Vec::new();
        let rounds = 200_000;
        let kth: Vec<f64> = (0..rounds)
            .map(|_| {
                s.sample_first_k(k, &mut out, &mut rng);
                out[k - 1]
            })
            .collect();
        let (m, v) = mean_var(&kth);
        let want_m = exponential_order_mean(n, k, lambda);
        let want_v = exponential_order_var(n, k, lambda);
        assert!((m - want_m).abs() < 0.003, "mean {m} want {want_m}");
        assert!((v - want_v).abs() < 0.003, "var {v} want {want_v}");
    }

    #[test]
    fn shift_offsets_every_arrival() {
        let plain = OrderStatSampler::exponential(20, 1.0);
        let shifted = OrderStatSampler::shifted_exponential(20, 1.5, 1.0);
        let (mut a, mut b) = (Pcg64::seed(3), Pcg64::seed(3));
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        plain.sample_first_k(6, &mut oa, &mut a);
        shifted.sample_first_k(6, &mut ob, &mut b);
        for (x, y) in oa.iter().zip(&ob) {
            assert!((y - x - 1.5).abs() < 1e-12);
        }
        assert_eq!(
            shifted.expected_kth(6).unwrap(),
            1.5 + plain.expected_kth(6).unwrap()
        );
    }

    #[test]
    fn pareto_minimum_is_pareto_with_rate_n_alpha() {
        // min of n Pareto(xm, α) ~ Pareto(xm, nα): pin the sampled
        // X_(1) mean against that closed form.
        let (n, xm, alpha) = (25, 1.0, 2.0);
        let s = OrderStatSampler::pareto(n, xm, alpha);
        let mut rng = Pcg64::seed(4);
        let mut out = Vec::new();
        let mins: Vec<f64> = (0..200_000)
            .map(|_| {
                s.sample_first_k(1, &mut out, &mut rng);
                out[0]
            })
            .collect();
        let (m, _) = mean_var(&mins);
        let na = n as f64 * alpha;
        let want = na * xm / (na - 1.0);
        assert!((m - want).abs() < 0.002, "min mean {m} want {want}");
        assert!(mins.iter().all(|&x| x >= xm));
    }

    #[test]
    fn weibull_minimum_is_rescaled_weibull() {
        // min of n Weibull(λ, k) ~ Weibull(λ·n^{−1/k}, k).
        let (n, lambda, k) = (16, 2.0, 1.5);
        let s = OrderStatSampler::weibull(n, lambda, k);
        let mut rng = Pcg64::seed(5);
        let mut out = Vec::new();
        let mins: Vec<f64> = (0..200_000)
            .map(|_| {
                s.sample_first_k(1, &mut out, &mut rng);
                out[0]
            })
            .collect();
        let (m, _) = mean_var(&mins);
        let want =
            Weibull::new(lambda * (n as f64).powf(-1.0 / k), k).mean();
        assert!((m - want).abs() < 0.005, "min mean {m} want {want}");
    }

    #[test]
    fn full_prefix_k_equals_n_matches_sorted_exhaustive_moments() {
        // k = n: the sampler emits the full order sequence; its per-rank
        // means must agree with sorting n exhaustive draws.
        let (n, lambda) = (8, 1.0);
        let s = OrderStatSampler::exponential(n, lambda);
        let d = crate::rng::Exponential::new(lambda);
        let rounds = 100_000;
        let mut fast = vec![0.0f64; n];
        let mut slow = vec![0.0f64; n];
        let mut rng_f = Pcg64::seed(6);
        let mut rng_s = Pcg64::seed(7);
        let mut out = Vec::new();
        let mut buf = vec![0.0f64; n];
        for _ in 0..rounds {
            s.sample_first_k(n, &mut out, &mut rng_f);
            for (acc, x) in fast.iter_mut().zip(&out) {
                *acc += x;
            }
            for slot in buf.iter_mut() {
                *slot = d.sample(&mut rng_s);
            }
            buf.sort_unstable_by(|a, b| a.total_cmp(b));
            for (acc, x) in slow.iter_mut().zip(&buf) {
                *acc += x;
            }
        }
        for (rank, (f, sl)) in fast.iter().zip(&slow).enumerate() {
            let (f, sl) = (f / rounds as f64, sl / rounds as f64);
            assert!(
                (f - sl).abs() < 0.02,
                "rank {rank}: fastpath mean {f} vs exhaustive {sl}"
            );
        }
    }

    #[test]
    fn incremental_stream_matches_batch_draws_bitwise() {
        // The stream form is the batch form: k stream_next calls from a
        // fresh state give sample_first_k's output bit for bit, for
        // every family — the pin the class-merge sampler's single-class
        // equivalence rests on.
        for s in [
            OrderStatSampler::exponential(30, 1.7),
            OrderStatSampler::shifted_exponential(30, 0.4, 1.7),
            OrderStatSampler::pareto(30, 1.0, 2.5),
            OrderStatSampler::weibull(30, 2.0, 1.5),
        ] {
            let mut batch_rng = Pcg64::seed(21);
            let mut stream_rng = Pcg64::seed(21);
            let mut out = Vec::new();
            s.sample_first_k(9, &mut out, &mut batch_rng);
            let mut st = s.stream_start();
            for want in &out {
                let got = s.stream_next(&mut st, &mut stream_rng);
                assert_eq!(got.to_bits(), want.to_bits(), "{}", s.name());
            }
            // The rng streams stayed aligned too (same draw count).
            assert_eq!(batch_rng.next_u64(), stream_rng.next_u64());
        }
    }

    #[test]
    #[should_panic(expected = "stream exhausted")]
    fn stream_rejects_draws_past_n() {
        let s = OrderStatSampler::exponential(3, 1.0);
        let mut rng = Pcg64::seed(0);
        let mut st = s.stream_start();
        for _ in 0..4 {
            s.stream_next(&mut st, &mut rng);
        }
    }

    #[test]
    #[should_panic(expected = "k must be in 1..=n")]
    fn rejects_k_out_of_range() {
        let s = OrderStatSampler::exponential(4, 1.0);
        s.sample_first_k(5, &mut Vec::new(), &mut Pcg64::seed(0));
    }
}
