//! Order statistics of worker response times.
//!
//! `X_(k)` — the k-th smallest of n iid response times — is THE quantity
//! the paper's runtime analysis is built on: one fastest-k iteration takes
//! exactly `X_(k)` wall-clock. We provide:
//!
//! * exact formulas for the exponential model (Rényi representation),
//! * a Monte-Carlo estimator for arbitrary delay models (used by the
//!   bound-optimal policy when delays are Pareto/Weibull/bimodal),
//! * an [`OrderStats`] table caching `(μ_k, σ_k²)` for k = 1..=n.

use crate::rng::Pcg64;
use crate::stats::{harmonic, harmonic_sq};
use crate::straggler::DelayModel;

/// `E[X_(k)]` for n iid `exp(lambda)` variables: `(H_n − H_{n−k})/λ`.
pub fn exponential_order_mean(n: usize, k: usize, lambda: f64) -> f64 {
    assert!(k >= 1 && k <= n, "k must be in 1..=n (got k={k}, n={n})");
    (harmonic(n) - harmonic(n - k)) / lambda
}

/// `Var[X_(k)]` for n iid `exp(lambda)`: `(H_n^(2) − H_{n−k}^(2))/λ²`.
pub fn exponential_order_var(n: usize, k: usize, lambda: f64) -> f64 {
    assert!(k >= 1 && k <= n, "k must be in 1..=n (got k={k}, n={n})");
    (harmonic_sq(n) - harmonic_sq(n - k)) / (lambda * lambda)
}

/// Cached `(μ_k, σ_k²)` for every k of a given delay model.
#[derive(Debug, Clone)]
pub struct OrderStats {
    n: usize,
    mean: Vec<f64>, // mean[k-1] = μ_k
    var: Vec<f64>,  // var[k-1]  = σ_k²
}

impl OrderStats {
    /// Exact table for the exponential model.
    pub fn exponential(n: usize, lambda: f64) -> Self {
        let mean = (1..=n)
            .map(|k| exponential_order_mean(n, k, lambda))
            .collect();
        let var = (1..=n)
            .map(|k| exponential_order_var(n, k, lambda))
            .collect();
        Self { n, mean, var }
    }

    /// Monte-Carlo table for an arbitrary delay model. `rounds` full draws
    /// of n response times; all k estimated from the same sorted samples.
    pub fn monte_carlo<D: DelayModel + ?Sized>(
        model: &D,
        n: usize,
        rounds: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg64::seed_stream(seed, 0x0515);
        let mut sum = vec![0.0f64; n];
        let mut sumsq = vec![0.0f64; n];
        let mut draw = vec![0.0f64; n];
        for round in 0..rounds {
            for (i, d) in draw.iter_mut().enumerate() {
                *d = model.sample(round as u64, i, &mut rng);
            }
            draw.sort_by(|a, b| a.total_cmp(b));
            for k in 0..n {
                sum[k] += draw[k];
                sumsq[k] += draw[k] * draw[k];
            }
        }
        let r = rounds as f64;
        let mean: Vec<f64> = sum.iter().map(|s| s / r).collect();
        let var = sumsq
            .iter()
            .zip(&mean)
            .map(|(sq, m)| (sq / r - m * m).max(0.0))
            .collect();
        Self { n, mean, var }
    }

    /// Number of workers n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// `μ_k = E[X_(k)]`, k in 1..=n.
    pub fn mean(&self, k: usize) -> f64 {
        assert!(k >= 1 && k <= self.n, "k out of range");
        self.mean[k - 1]
    }

    /// `σ_k² = Var[X_(k)]`, k in 1..=n.
    pub fn var(&self, k: usize) -> f64 {
        assert!(k >= 1 && k <= self.n, "k out of range");
        self.var[k - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::ExponentialDelays;

    #[test]
    fn example1_harmonic_means() {
        // Paper Example 1: μ_k = H_n − H_{n−k} (λ = 1), n = 5.
        let n = 5;
        let h5 = harmonic(5);
        for k in 1..=n {
            let want = h5 - harmonic(n - k);
            assert!((exponential_order_mean(n, k, 1.0) - want).abs() < 1e-12);
        }
        // Min of 5 exp(1) has mean 1/5.
        assert!((exponential_order_mean(5, 1, 1.0) - 0.2).abs() < 1e-12);
        // Max has mean H_5.
        assert!((exponential_order_mean(5, 5, 1.0) - h5).abs() < 1e-12);
    }

    #[test]
    fn mean_is_increasing_in_k() {
        let table = OrderStats::exponential(50, 1.0);
        for k in 2..=50 {
            assert!(table.mean(k) > table.mean(k - 1));
        }
    }

    #[test]
    fn rate_scales_means() {
        // exp(λ): μ_k(λ) = μ_k(1)/λ.
        for k in [1, 3, 5] {
            let a = exponential_order_mean(5, k, 1.0);
            let b = exponential_order_mean(5, k, 5.0);
            assert!((a / 5.0 - b).abs() < 1e-12);
        }
    }

    #[test]
    fn monte_carlo_matches_exponential_exact() {
        let model = ExponentialDelays::new(1.0);
        let mc = OrderStats::monte_carlo(&model, 10, 60_000, 42);
        let exact = OrderStats::exponential(10, 1.0);
        for k in 1..=10 {
            let rel = (mc.mean(k) - exact.mean(k)).abs() / exact.mean(k);
            assert!(rel < 0.02, "k={k}: {} vs {}", mc.mean(k), exact.mean(k));
            let relv = (mc.var(k) - exact.var(k)).abs() / exact.var(k);
            assert!(relv < 0.1, "k={k} var: {} vs {}", mc.var(k), exact.var(k));
        }
    }

    #[test]
    fn monte_carlo_survives_nan_delays() {
        // Regression: a model emitting NaN (e.g. a trace with a 0/0
        // rate) used to panic the partial_cmp sort inside
        // monte_carlo; under total_cmp NaN draws order slowest and
        // only pollute the top order statistics.
        struct SometimesNan;
        impl DelayModel for SometimesNan {
            fn sample(
                &self,
                _iteration: u64,
                worker: usize,
                _rng: &mut dyn crate::straggler::RngDyn,
            ) -> f64 {
                if worker == 0 {
                    f64::NAN
                } else {
                    worker as f64
                }
            }
            fn name(&self) -> String {
                "sometimes-nan".to_string()
            }
        }
        let mc = OrderStats::monte_carlo(&SometimesNan, 4, 100, 7);
        // Finite draws 1,2,3 occupy the bottom three slots each round.
        for k in 1..=3 {
            assert!((mc.mean(k) - k as f64).abs() < 1e-12);
        }
        assert!(mc.mean(4).is_nan());
    }

    #[test]
    #[should_panic(expected = "k must be in 1..=n")]
    fn k_zero_rejected() {
        exponential_order_mean(5, 0, 1.0);
    }
}
