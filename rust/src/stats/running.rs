//! Welford running moments + quantile helper.

/// Numerically stable running mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold in one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Count of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 if empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (0 if < 2 observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum observed.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observed.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// q-quantile (0 ≤ q ≤ 1) by sorting a copy; linear interpolation.
/// NaN inputs sort last under `total_cmp` instead of panicking.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q must be in [0,1]");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut rs = RunningStats::new();
        for &x in &xs {
            rs.push(x);
        }
        assert_eq!(rs.count(), 8);
        assert!((rs.mean() - 5.0).abs() < 1e-12);
        // Unbiased variance = 32/7.
        assert!((rs.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(rs.min(), 2.0);
        assert_eq!(rs.max(), 9.0);
    }

    #[test]
    fn empty_is_safe() {
        let rs = RunningStats::new();
        assert_eq!(rs.mean(), 0.0);
        assert_eq!(rs.variance(), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
        assert!((quantile(&xs, 0.1) - 1.4).abs() < 1e-12);
    }

    #[test]
    fn quantile_survives_nan_samples() {
        // Regression: a NaN sample (e.g. a 0/0 rate from an empty
        // window) used to panic the partial_cmp sort; under total_cmp
        // it orders last and the finite quantiles are unaffected.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert!((quantile(&xs, 1.0 / 3.0) - 2.0).abs() < 1e-12);
        assert!(quantile(&xs, 1.0).is_nan());
    }
}
