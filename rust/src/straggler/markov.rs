//! Markov-modulated (time-correlated) delay model.
//!
//! Real clusters straggle in *bursts* — a worker that was slow at
//! iteration j is likely still slow at j+1 (background jobs, thermal
//! throttling). Each worker carries a 2-state Markov chain
//! (fast ⇄ slow); its delay is exp(λ) scaled by `slow_factor` in the slow
//! state. Violates the paper's iid-across-iterations assumption — used by
//! ablations to probe how the Pflug policy degrades under correlation.
//!
//! Chain state is derived deterministically from (worker, iteration) by
//! replaying the chain forward, so the model stays stateless/Sync like
//! every other [`DelayModel`].

use super::{DelayModel, DynRng, RngDyn};
use crate::rng::{Distribution, Exponential, Pcg64, Rng};

/// Two-state Markov-modulated exponential delays.
#[derive(Debug, Clone)]
pub struct MarkovDelays {
    base: Exponential,
    /// P(fast → slow) per iteration.
    pub p_fs: f64,
    /// P(slow → fast) per iteration.
    pub p_sf: f64,
    /// Multiplier while slow.
    pub slow_factor: f64,
    /// Chain seed (separate from the jitter stream the master provides).
    pub seed: u64,
}

impl MarkovDelays {
    /// New model; burst length ~ 1/p_sf iterations.
    pub fn new(lambda: f64, p_fs: f64, p_sf: f64, slow_factor: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&p_fs) && (0.0..=1.0).contains(&p_sf));
        assert!(slow_factor >= 1.0);
        Self { base: Exponential::new(lambda), p_fs, p_sf, slow_factor, seed }
    }

    /// Whether `worker` is in the slow state at `iteration` (stationary
    /// start, chain replayed deterministically).
    pub fn is_slow(&self, iteration: u64, worker: usize) -> bool {
        let mut chain = Pcg64::seed_stream(self.seed, worker as u64);
        // Stationary initial state: P(slow) = p_fs / (p_fs + p_sf).
        let p_slow0 = if self.p_fs + self.p_sf > 0.0 {
            self.p_fs / (self.p_fs + self.p_sf)
        } else {
            0.0
        };
        let mut slow = chain.next_f64() < p_slow0;
        for _ in 0..iteration {
            let u = chain.next_f64();
            slow = if slow { u >= self.p_sf } else { u < self.p_fs };
        }
        slow
    }
}

impl DelayModel for MarkovDelays {
    fn sample(&self, iteration: u64, worker: usize, rng: &mut dyn RngDyn) -> f64 {
        let x = self.base.sample(&mut DynRng(rng));
        if self.is_slow(iteration, worker) {
            x * self.slow_factor
        } else {
            x
        }
    }
    fn name(&self) -> String {
        format!(
            "markov(p_fs={}, p_sf={}, factor={})",
            self.p_fs, self.p_sf, self.slow_factor
        )
    }
    fn is_iid(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn state_is_deterministic() {
        let m = MarkovDelays::new(1.0, 0.1, 0.3, 10.0, 42);
        for it in [0u64, 5, 100] {
            for w in 0..4 {
                assert_eq!(m.is_slow(it, w), m.is_slow(it, w));
            }
        }
    }

    #[test]
    fn bursts_are_correlated() {
        // P(slow at t+1 | slow at t) = 1 − p_sf = 0.9 ≫ stationary P(slow).
        let m = MarkovDelays::new(1.0, 0.05, 0.1, 10.0, 7);
        let mut joint = 0usize;
        let mut slow_t = 0usize;
        for w in 0..50 {
            for it in 0..200u64 {
                if m.is_slow(it, w) {
                    slow_t += 1;
                    if m.is_slow(it + 1, w) {
                        joint += 1;
                    }
                }
            }
        }
        assert!(slow_t > 100, "need slow samples, got {slow_t}");
        let cond = joint as f64 / slow_t as f64;
        assert!(cond > 0.8, "P(slow|slow) = {cond} should be ~0.9");
    }

    #[test]
    fn stationary_fraction_matches() {
        let m = MarkovDelays::new(1.0, 0.1, 0.3, 5.0, 9);
        let mut slow = 0usize;
        let total = 50 * 400;
        for w in 0..50 {
            for it in 0..400u64 {
                if m.is_slow(it, w) {
                    slow += 1;
                }
            }
        }
        let frac = slow as f64 / total as f64;
        let want = 0.1 / 0.4;
        assert!((frac - want).abs() < 0.05, "frac={frac} want={want}");
    }

    #[test]
    fn slow_state_scales_delay() {
        let m = MarkovDelays::new(1.0, 1.0, 0.0, 10.0, 1); // always slow after step 0
        let mut rng = Pcg64::seed(3);
        let mut mean = 0.0;
        let n = 20_000;
        for i in 0..n {
            mean += m.sample(10, 0, &mut rng);
            let _ = i;
        }
        mean /= n as f64;
        assert!(mean > 5.0, "slow-state mean should be ~10, got {mean}");
    }
}
