//! Straggler (worker response-time) models.
//!
//! The paper assumes response times `X_1..X_n` iid across workers and
//! iterations, exponential in §V. We implement that model exactly, plus the
//! heavier-tailed and non-iid variants used in the ablation benches — the
//! substitution for "a physical cluster with naturally random delays"
//! (DESIGN.md §3): all of the paper's quantities depend on delays only
//! through their order statistics, which each model reproduces by
//! construction.
//!
//! A model is queried once per (iteration, worker) pair and must be
//! deterministic given the rng stream — the simulator and the threaded
//! executor both consume the same draws, so results agree bit-for-bit
//! across execution modes.

mod markov;
mod models;
mod trace;

pub use models::{
    BimodalDelays, ExponentialDelays, ParetoDelays, ShiftedExponentialDelays,
    WeibullDelays,
};
pub use markov::MarkovDelays;
pub use trace::TraceDelays;

use crate::rng::Rng;

/// A worker response-time model.
pub trait DelayModel: Send + Sync {
    /// Response time of `worker` at `iteration` (> 0, finite).
    fn sample(&self, iteration: u64, worker: usize, rng: &mut dyn RngDyn) -> f64;

    /// Human-readable name for reports.
    fn name(&self) -> String;

    /// True if draws are iid across workers and iterations (the paper's
    /// assumption; policies may exploit it).
    fn is_iid(&self) -> bool {
        true
    }
}

/// Object-safe shim over [`Rng`] so `DelayModel` can be a trait object.
pub trait RngDyn {
    /// Next 64 random bits.
    fn next_u64_dyn(&mut self) -> u64;
}

impl<R: Rng> RngDyn for R {
    fn next_u64_dyn(&mut self) -> u64 {
        self.next_u64()
    }
}

/// Adapter giving `&mut dyn RngDyn` the full [`Rng`] API.
pub struct DynRng<'a>(pub &'a mut dyn RngDyn);

impl Rng for DynRng<'_> {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.0.next_u64_dyn()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn dyn_rng_round_trip() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(1);
        let via_dyn = {
            let d: &mut dyn RngDyn = &mut a;
            DynRng(d).next_u64()
        };
        assert_eq!(via_dyn, b.next_u64());
    }

    #[test]
    fn models_are_object_safe() {
        let models: Vec<Box<dyn DelayModel>> = vec![
            Box::new(ExponentialDelays::new(1.0)),
            Box::new(ParetoDelays::new(1.0, 2.5)),
        ];
        let mut rng = Pcg64::seed(2);
        for m in &models {
            assert!(m.sample(0, 0, &mut rng) > 0.0);
            assert!(!m.name().is_empty());
        }
    }
}
