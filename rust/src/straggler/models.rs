//! Concrete delay models.

use super::{DelayModel, DynRng, RngDyn};
use crate::rng::{Bernoulli, Distribution, Exponential, Pareto, Weibull};

/// iid `exp(λ)` response times — the paper's §V model (λ = 1 in Figs. 2–3).
#[derive(Debug, Clone)]
pub struct ExponentialDelays {
    dist: Exponential,
}

impl ExponentialDelays {
    pub fn new(lambda: f64) -> Self {
        Self { dist: Exponential::new(lambda) }
    }

    /// The rate λ.
    pub fn lambda(&self) -> f64 {
        self.dist.lambda
    }
}

impl DelayModel for ExponentialDelays {
    fn sample(&self, _it: u64, _w: usize, rng: &mut dyn RngDyn) -> f64 {
        self.dist.sample(&mut DynRng(rng))
    }
    fn name(&self) -> String {
        format!("exp(lambda={})", self.dist.lambda)
    }
}

/// Constant setup cost plus exponential tail: `Δ + exp(λ)`. The classic
/// model for "every worker pays a fixed compute time, straggling is in the
/// tail" (Lee et al. 2018).
#[derive(Debug, Clone)]
pub struct ShiftedExponentialDelays {
    pub shift: f64,
    dist: Exponential,
}

impl ShiftedExponentialDelays {
    pub fn new(shift: f64, lambda: f64) -> Self {
        assert!(shift >= 0.0, "shift must be non-negative");
        Self { shift, dist: Exponential::new(lambda) }
    }
}

impl DelayModel for ShiftedExponentialDelays {
    fn sample(&self, _it: u64, _w: usize, rng: &mut dyn RngDyn) -> f64 {
        self.shift + self.dist.sample(&mut DynRng(rng))
    }
    fn name(&self) -> String {
        format!("shifted-exp(shift={}, lambda={})", self.shift, self.dist.lambda)
    }
}

/// Heavy-tailed Pareto response times — stress test for the adaptive policy
/// when `E[X_(n)]` is dominated by rare huge stalls.
#[derive(Debug, Clone)]
pub struct ParetoDelays {
    dist: Pareto,
}

impl ParetoDelays {
    pub fn new(xm: f64, alpha: f64) -> Self {
        Self { dist: Pareto::new(xm, alpha) }
    }
}

impl DelayModel for ParetoDelays {
    fn sample(&self, _it: u64, _w: usize, rng: &mut dyn RngDyn) -> f64 {
        self.dist.sample(&mut DynRng(rng))
    }
    fn name(&self) -> String {
        format!("pareto(xm={}, alpha={})", self.dist.xm, self.dist.alpha)
    }
}

/// Weibull response times (shape < 1: heavier than exponential).
#[derive(Debug, Clone)]
pub struct WeibullDelays {
    dist: Weibull,
}

impl WeibullDelays {
    pub fn new(lambda: f64, k: f64) -> Self {
        Self { dist: Weibull::new(lambda, k) }
    }
}

impl DelayModel for WeibullDelays {
    fn sample(&self, _it: u64, _w: usize, rng: &mut dyn RngDyn) -> f64 {
        self.dist.sample(&mut DynRng(rng))
    }
    fn name(&self) -> String {
        format!("weibull(lambda={}, k={})", self.dist.lambda, self.dist.k)
    }
}

/// Non-iid extension: a fixed subset of workers is *persistently* slow
/// (their draws are scaled by `slow_factor`), modelling degraded hosts
/// rather than transient noise. With `p_slow` per-iteration mode mixing on
/// top, this reproduces the bimodal delay profiles of real clusters
/// ("tail at scale", Dean & Barroso 2013).
#[derive(Debug, Clone)]
pub struct BimodalDelays {
    base: Exponential,
    /// Workers with index < `n_slow` are persistently slow.
    pub n_slow: usize,
    /// Multiplier applied to slow workers' draws.
    pub slow_factor: f64,
    /// Probability that a *fast* worker transiently straggles anyway.
    transient: Bernoulli,
}

impl BimodalDelays {
    pub fn new(lambda: f64, n_slow: usize, slow_factor: f64, p_transient: f64) -> Self {
        assert!(slow_factor >= 1.0, "slow_factor must be >= 1");
        Self {
            base: Exponential::new(lambda),
            n_slow,
            slow_factor,
            transient: Bernoulli::new(p_transient),
        }
    }

    /// Base rate λ of the fast mode.
    pub fn lambda(&self) -> f64 {
        self.base.lambda
    }

    /// Probability that a fast worker transiently straggles.
    pub fn p_transient(&self) -> f64 {
        self.transient.p
    }

    /// Effective rate of the persistently slow mode: scaling an
    /// `Exp(λ)` draw by `slow_factor` yields `Exp(λ / slow_factor)`.
    pub fn slow_lambda(&self) -> f64 {
        self.base.lambda / self.slow_factor
    }
}

impl DelayModel for BimodalDelays {
    fn sample(&self, _it: u64, worker: usize, rng: &mut dyn RngDyn) -> f64 {
        let mut r = DynRng(rng);
        let x = self.base.sample(&mut r);
        if worker < self.n_slow || self.transient.flip(&mut r) {
            x * self.slow_factor
        } else {
            x
        }
    }
    fn name(&self) -> String {
        format!(
            "bimodal(n_slow={}, factor={}, p_transient={})",
            self.n_slow, self.slow_factor, self.transient.p
        )
    }
    fn is_iid(&self) -> bool {
        self.n_slow == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::stats::RunningStats;

    fn mean_of<M: DelayModel>(m: &M, worker: usize, n: usize, seed: u64) -> f64 {
        let mut rng = Pcg64::seed(seed);
        let mut rs = RunningStats::new();
        for it in 0..n {
            rs.push(m.sample(it as u64, worker, &mut rng));
        }
        rs.mean()
    }

    #[test]
    fn exponential_mean() {
        let m = ExponentialDelays::new(2.0);
        assert!((mean_of(&m, 0, 100_000, 1) - 0.5).abs() < 0.01);
    }

    #[test]
    fn shifted_exponential_floor() {
        let m = ShiftedExponentialDelays::new(1.5, 1.0);
        let mut rng = Pcg64::seed(2);
        for it in 0..10_000 {
            assert!(m.sample(it, 0, &mut rng) >= 1.5);
        }
        assert!((mean_of(&m, 0, 100_000, 3) - 2.5).abs() < 0.02);
    }

    #[test]
    fn bimodal_slow_workers_are_slower() {
        let m = BimodalDelays::new(1.0, 2, 10.0, 0.0);
        let slow = mean_of(&m, 0, 50_000, 4);
        let fast = mean_of(&m, 5, 50_000, 5);
        assert!(slow > 5.0 * fast, "slow={slow} fast={fast}");
        assert!(!m.is_iid());
    }

    #[test]
    fn bimodal_accessors_expose_the_two_class_rates() {
        let m = BimodalDelays::new(2.0, 3, 8.0, 0.25);
        assert_eq!(m.lambda(), 2.0);
        assert_eq!(m.slow_lambda(), 0.25);
        assert_eq!(m.p_transient(), 0.25);
        // Scaled-exponential law: the slow group's empirical mean
        // matches 1 / slow_lambda().
        let frozen = BimodalDelays::new(2.0, 3, 8.0, 0.0);
        let slow_mean = mean_of(&frozen, 0, 100_000, 8);
        assert!(
            (slow_mean - 1.0 / frozen.slow_lambda()).abs() < 0.05,
            "{slow_mean}"
        );
    }

    #[test]
    fn pareto_min_is_xm() {
        let m = ParetoDelays::new(2.0, 3.0);
        let mut rng = Pcg64::seed(6);
        for it in 0..10_000 {
            assert!(m.sample(it, 0, &mut rng) >= 2.0);
        }
    }

    #[test]
    fn weibull_positive() {
        let m = WeibullDelays::new(1.0, 0.7);
        let mut rng = Pcg64::seed(7);
        for it in 0..10_000 {
            assert!(m.sample(it, 0, &mut rng) > 0.0);
        }
    }
}
