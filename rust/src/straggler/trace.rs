//! Empirical trace replay.
//!
//! Substitution for production delay traces (which we don't have): a
//! `TraceDelays` replays a recorded `(iteration x worker)` table of
//! response times, cycling if the run outlives the trace. Traces can be
//! loaded from a simple CSV (one iteration per line) or synthesized and
//! saved by the workload generator, so benches are reproducible inputs
//! rather than live draws.

use super::{DelayModel, RngDyn};

/// Replay of a fixed delay table.
#[derive(Debug, Clone)]
pub struct TraceDelays {
    /// `rows x n_workers` response times.
    table: Vec<Vec<f64>>,
    name: String,
}

impl TraceDelays {
    /// Build from an in-memory table (each row = one iteration).
    pub fn new(table: Vec<Vec<f64>>) -> Self {
        assert!(!table.is_empty(), "trace must have at least one row");
        let w = table[0].len();
        assert!(w > 0, "trace rows must be non-empty");
        assert!(
            table.iter().all(|r| r.len() == w),
            "all trace rows must have the same worker count"
        );
        assert!(
            table.iter().flatten().all(|&x| x.is_finite() && x > 0.0),
            "trace delays must be positive and finite"
        );
        Self { table, name: "trace(memory)".into() }
    }

    /// Parse a CSV string: one iteration per line, comma-separated delays.
    pub fn from_csv(text: &str) -> Result<Self, String> {
        let mut table = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let row: Result<Vec<f64>, _> = line
                .split(',')
                .map(|tok| tok.trim().parse::<f64>())
                .collect();
            let row =
                row.map_err(|e| format!("trace line {}: {e}", lineno + 1))?;
            table.push(row);
        }
        if table.is_empty() {
            return Err("trace csv has no data rows".into());
        }
        let w = table[0].len();
        if !table.iter().all(|r| r.len() == w) {
            return Err("trace csv rows have inconsistent widths".into());
        }
        let mut t = Self::new(table);
        t.name = "trace(csv)".into();
        Ok(t)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let mut t = Self::from_csv(&text)?;
        t.name = format!("trace({})", path.display());
        Ok(t)
    }

    /// Mine a recorded binary event trace (see [`crate::trace`]) into a
    /// replayable straggler scenario: the *raw* (pre-scale, pre-comm)
    /// delay draw of every `Compute` event becomes one table cell, keyed
    /// `(iteration, worker)`. Only the leading run of *complete* rows
    /// (all `n_workers` drawn) is kept, so a truncated last round never
    /// yields a partial row. Round disciplines record one draw per
    /// worker per round; the async disciplines draw all workers only at
    /// start-up, so their traces mine to a single row (which cycles).
    ///
    /// This is how a recorded experiment's delay *sequence* gets reused
    /// against new policies, channels, or codes: mine once, then run any
    /// configuration with the mined model.
    pub fn from_event_trace(
        trace: &crate::trace::Trace,
    ) -> Result<Self, String> {
        let n = trace.n_workers as usize;
        if n == 0 {
            return Err("event trace reports 0 workers".into());
        }
        let mut table: Vec<Vec<Option<f64>>> = Vec::new();
        for ev in &trace.events {
            if let crate::trace::Event::Compute {
                iteration, worker, raw, ..
            } = *ev
            {
                let (it, w) = (iteration as usize, worker as usize);
                if w >= n {
                    return Err(format!(
                        "event trace is corrupt: compute event for worker \
                         {w} but the header says {n} workers"
                    ));
                }
                if it >= table.len() {
                    table.resize(it + 1, vec![None; n]);
                }
                if !(raw.is_finite() && raw > 0.0) {
                    return Err(format!(
                        "recorded delay for (iteration {it}, worker {w}) \
                         is {raw}; mined delays must be positive and \
                         finite"
                    ));
                }
                table[it][w] = Some(raw);
            }
        }
        // Keep the leading run of complete rows.
        let complete: Vec<Vec<f64>> = table
            .into_iter()
            .map(|row| row.into_iter().collect::<Option<Vec<f64>>>())
            .take_while(|row| row.is_some())
            .map(|row| row.expect("take_while kept only Some rows"))
            .collect();
        if complete.is_empty() {
            return Err(
                "event trace has no complete iteration of compute events \
                 to mine"
                    .into(),
            );
        }
        let mut t = Self::new(complete);
        t.name = format!("trace(events:{})", trace.label);
        Ok(t)
    }

    /// Number of workers per row.
    pub fn workers(&self) -> usize {
        self.table[0].len()
    }

    /// Number of recorded iterations.
    pub fn len(&self) -> usize {
        self.table.len()
    }

    /// True if empty (never — construction forbids it; for clippy symmetry).
    pub fn is_empty(&self) -> bool {
        false
    }
}

impl DelayModel for TraceDelays {
    fn sample(&self, iteration: u64, worker: usize, _rng: &mut dyn RngDyn) -> f64 {
        let row = &self.table[(iteration as usize) % self.table.len()];
        row[worker % row.len()]
    }
    fn name(&self) -> String {
        self.name.clone()
    }
    fn is_iid(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn replay_and_cycle() {
        let t = TraceDelays::new(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut rng = Pcg64::seed(0);
        assert_eq!(t.sample(0, 0, &mut rng), 1.0);
        assert_eq!(t.sample(0, 1, &mut rng), 2.0);
        assert_eq!(t.sample(1, 1, &mut rng), 4.0);
        assert_eq!(t.sample(2, 0, &mut rng), 1.0); // cycles
        assert_eq!(t.workers(), 2);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn csv_round_trip() {
        let t = TraceDelays::from_csv("# comment\n1.0, 2.5\n0.5, 3.5\n").unwrap();
        let mut rng = Pcg64::seed(0);
        assert_eq!(t.sample(1, 1, &mut rng), 3.5);
    }

    #[test]
    fn csv_errors() {
        assert!(TraceDelays::from_csv("").is_err());
        assert!(TraceDelays::from_csv("1.0,x").is_err());
        assert!(TraceDelays::from_csv("1.0\n1.0,2.0").is_err());
    }

    #[test]
    #[should_panic(expected = "positive and finite")]
    fn rejects_nonpositive() {
        TraceDelays::new(vec![vec![0.0]]);
    }

    #[test]
    fn mines_compute_events_keeping_complete_rows() {
        use crate::trace::{Discipline, Event, Trace};
        let mut tr = Trace::new(Discipline::Sync, 2, "mine");
        let compute = |iteration, worker, raw| Event::Compute {
            iteration,
            worker,
            raw,
            compute: raw,
            upload: 0.0,
            download: 0.0,
        };
        tr.push(compute(0, 0, 1.5));
        tr.push(compute(0, 1, 2.5));
        tr.push(compute(1, 1, 4.0));
        tr.push(compute(1, 0, 3.0)); // out of order within the round: fine
        tr.push(compute(2, 0, 9.0)); // truncated round: dropped
        let t = TraceDelays::from_event_trace(&tr).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.workers(), 2);
        let mut rng = Pcg64::seed(0);
        assert_eq!(t.sample(0, 0, &mut rng), 1.5);
        assert_eq!(t.sample(1, 0, &mut rng), 3.0);
        assert_eq!(t.sample(1, 1, &mut rng), 4.0);
        assert_eq!(t.sample(2, 1, &mut rng), 2.5); // cycles
        assert!(t.name().contains("events:mine"), "{}", t.name());
    }

    #[test]
    fn mining_rejects_traces_without_a_complete_round() {
        use crate::trace::{Discipline, Event, Trace};
        let mut tr = Trace::new(Discipline::Sync, 2, "partial");
        tr.push(Event::Compute {
            iteration: 0,
            worker: 0,
            raw: 1.0,
            compute: 1.0,
            upload: 0.0,
            download: 0.0,
        });
        let err = TraceDelays::from_event_trace(&tr).unwrap_err();
        assert!(err.contains("complete"), "{err}");
        let empty = Trace::new(Discipline::Sync, 2, "empty");
        assert!(TraceDelays::from_event_trace(&empty).is_err());
    }
}
