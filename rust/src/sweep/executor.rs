//! Parallel, deterministic execution of [`RunSpec`] lists.
//!
//! [`SweepExecutor`] fans independent specs out over an
//! [`exec::ThreadPool`](crate::exec::ThreadPool), collects results over
//! a channel, and reassembles them **in spec order** — combined with the
//! per-spec seed rule ([`super::derive_seed`]) this makes `jobs = 1` and
//! `jobs = N` produce bit-for-bit identical outputs (test-asserted by
//! `rust/tests/test_sweep_equivalence.rs`).

use super::RunSpec;
use crate::config::ExperimentConfig;
use crate::coordinator::{run_experiment, ExperimentOutput};
use crate::exec::ThreadPool;
use crate::metrics::{write_csv_with_scalars, CsvError, Recorder, RunScalars};
use std::path::Path;
use std::sync::Arc;

/// Runs experiment specs, sequentially or on a thread pool.
#[derive(Debug, Clone, Copy)]
pub struct SweepExecutor {
    jobs: usize,
}

impl SweepExecutor {
    /// Executor with `jobs` worker threads; `0` resolves to the
    /// machine's available parallelism (the `--jobs` / `[run] jobs`
    /// convention). The worker count never affects results, only
    /// wall-clock.
    pub fn new(jobs: usize) -> Self {
        let jobs = if jobs == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            jobs
        };
        Self { jobs }
    }

    /// Single-threaded executor (the reference order of execution).
    pub fn sequential() -> Self {
        Self { jobs: 1 }
    }

    /// Resolved worker count (≥ 1).
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Run every spec and return the outputs in spec order.
    ///
    /// Each spec is executed as a pure function of its own config (its
    /// RNG streams derive from `spec.cfg.seed`; no state is shared), so
    /// the thread schedule cannot reach the results. On failure the
    /// first error *in spec order* is returned; the parallel path may
    /// have run later specs already, but their outputs are discarded, so
    /// the observable result still matches sequential execution.
    pub fn run(
        &self,
        specs: &[RunSpec],
    ) -> Result<Vec<ExperimentOutput>, String> {
        // Fail fast on construction errors before running anything: a
        // bad axis value (cross-field constraint, workload the native
        // runner rejects, delay-model parameter) must not cost the rest
        // of the grid's compute — on the sequential path either.
        // Scanned in spec order and through the same checks
        // run_experiment performs, so the reported error is the one the
        // plain spec-by-spec loop would hit first. Delay models are
        // probe-built once per *distinct* spec (a repeat sweep shares
        // one; a trace model re-reads its file only once here).
        let mut delays_checked: Vec<&crate::config::DelaySpec> = Vec::new();
        for spec in specs {
            spec.cfg.validate()?;
            crate::coordinator::reject_non_native(&spec.cfg)?;
            if !delays_checked.contains(&&spec.cfg.delays) {
                spec.cfg.delays.build()?;
                delays_checked.push(&spec.cfg.delays);
            }
        }
        if self.jobs == 1 || specs.len() <= 1 {
            return specs.iter().map(|s| run_experiment(&s.cfg)).collect();
        }
        let cfgs: Arc<Vec<ExperimentConfig>> =
            Arc::new(specs.iter().map(|s| s.cfg.clone()).collect());
        let pool = ThreadPool::new(self.jobs.min(specs.len()))?;
        let results =
            pool.map(specs.len(), move |i| run_experiment(&cfgs[i]));
        let mut outs = Vec::with_capacity(results.len());
        for r in results {
            outs.push(r?);
        }
        Ok(outs)
    }

    /// Order-preserving parallel map for sweep-adjacent work that is not
    /// an [`ExperimentConfig`] run (theory curves, custom-channel
    /// drivers in benches). `f` must be a pure function of `i` for the
    /// jobs-invariance contract to hold.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        if self.jobs == 1 || n <= 1 {
            (0..n).map(f).collect()
        } else {
            let pool = ThreadPool::new(self.jobs.min(n))
                .expect("resolved executor jobs are >= 1");
            pool.map(n, f)
        }
    }
}

/// Run-header meta lines for a spec list: a `sweep:` summary line (run
/// count + axis names) followed by one line per run recording its
/// scenario axes and seed.
pub fn sweep_meta(specs: &[RunSpec]) -> Vec<String> {
    let mut axis_names: Vec<&str> = Vec::new();
    for spec in specs {
        for (name, _) in &spec.axes {
            if !axis_names.contains(&name.as_str()) {
                axis_names.push(name);
            }
        }
    }
    let over = if axis_names.is_empty() {
        String::new()
    } else {
        format!(" over {}", axis_names.join(" x "))
    };
    let mut meta = Vec::with_capacity(specs.len() + 1);
    meta.push(format!("sweep: {} runs{over}", specs.len()));
    meta.extend(specs.iter().map(|s| s.meta_line()));
    meta
}

/// Write a sweep's series through the unified CSV path
/// ([`metrics::write_csv_with_scalars`](write_csv_with_scalars)): the
/// scenario axes become run-header meta lines, so a results file records
/// *what* produced each series, not just the numbers, and each run's
/// whole-run scalars (`late_responses`, `mean_staleness`) fill the v4
/// columns.
pub fn write_sweep_csv(
    path: &Path,
    specs: &[RunSpec],
    outs: &[ExperimentOutput],
) -> Result<(), CsvError> {
    assert_eq!(
        specs.len(),
        outs.len(),
        "one output per spec (pass the executor's result unmodified)"
    );
    let runs: Vec<(&Recorder, RunScalars)> = outs
        .iter()
        .map(|o| {
            (
                &o.recorder,
                RunScalars {
                    late_responses: o.late_responses,
                    mean_staleness: o.mean_staleness,
                },
            )
        })
        .collect();
    write_csv_with_scalars(path, &runs, &sweep_meta(specs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicySpec, WorkloadSpec};
    use crate::sweep::SweepGrid;

    fn tiny() -> ExperimentConfig {
        ExperimentConfig {
            label: "tiny".into(),
            n: 5,
            max_iterations: 40,
            max_time: 0.0,
            record_stride: 10,
            policy: PolicySpec::Fixed { k: 2 },
            workload: WorkloadSpec::LinReg { m: 50, d: 5 },
            ..Default::default()
        }
    }

    fn tiny_specs() -> Vec<RunSpec> {
        SweepGrid::new(tiny())
            .axis_over(
                "k",
                vec![1usize, 2, 4],
                |k| format!("k={k}"),
                |k, cfg| cfg.policy = PolicySpec::Fixed { k: *k },
            )
            .axis_over(
                "seed",
                vec![0u64, 1],
                |s| format!("s{s}"),
                |s, cfg| cfg.seed = *s,
            )
            .build()
    }

    #[test]
    fn parallel_run_matches_sequential_bitwise() {
        let specs = tiny_specs();
        let seq = SweepExecutor::sequential().run(&specs).unwrap();
        let par = SweepExecutor::new(4).run(&specs).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.recorder.label, b.recorder.label);
            assert_eq!(a.recorder.samples(), b.recorder.samples());
            assert_eq!(a.steps, b.steps);
            assert!(a.total_time.to_bits() == b.total_time.to_bits());
        }
    }

    #[test]
    fn errors_surface_in_spec_order() {
        let mut specs = tiny_specs();
        // Corrupt the *second* spec; both paths must report this one.
        specs[1].cfg.n = 0;
        let seq = SweepExecutor::sequential().run(&specs).unwrap_err();
        let par = SweepExecutor::new(3).run(&specs).unwrap_err();
        assert_eq!(seq, par);
        assert!(seq.contains("n must be"), "{seq}");
    }

    #[test]
    fn map_is_order_preserving() {
        let seq = SweepExecutor::sequential().map(20, |i| 3 * i);
        let par = SweepExecutor::new(5).map(20, |i| 3 * i);
        assert_eq!(seq, par);
        assert_eq!(seq[7], 21);
    }

    #[test]
    fn zero_jobs_resolves_to_available_parallelism() {
        assert!(SweepExecutor::new(0).jobs() >= 1);
        assert_eq!(SweepExecutor::new(3).jobs(), 3);
        assert_eq!(SweepExecutor::sequential().jobs(), 1);
    }

    #[test]
    fn meta_lines_record_axes_and_seeds() {
        let specs = tiny_specs();
        let meta = sweep_meta(&specs);
        assert_eq!(meta.len(), specs.len() + 1);
        assert_eq!(meta[0], "sweep: 6 runs over k x seed");
        assert_eq!(meta[1], "run k=1/s0: k=k=1 seed=s0 rng_seed=0");
        assert_eq!(meta[6], "run k=4/s1: k=k=4 seed=s1 rng_seed=1");
    }

    #[test]
    fn sweep_csv_is_jobs_invariant() {
        let specs = tiny_specs();
        let dir = std::env::temp_dir().join("adasgd_sweep_csv_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("jobs1.csv");
        let p4 = dir.join("jobs4.csv");
        let seq = SweepExecutor::sequential().run(&specs).unwrap();
        let par = SweepExecutor::new(4).run(&specs).unwrap();
        write_sweep_csv(&p1, &specs, &seq).unwrap();
        write_sweep_csv(&p4, &specs, &par).unwrap();
        let b1 = std::fs::read(&p1).unwrap();
        let b4 = std::fs::read(&p4).unwrap();
        assert!(!b1.is_empty());
        assert_eq!(b1, b4, "jobs must never reach the CSV bytes");
        std::fs::remove_dir_all(&dir).ok();
    }
}
