//! Parallel deterministic experiment sweeps.
//!
//! Every figure in the paper (and in the Dutta-et-al. and
//! communication-efficient comparators this repo reproduces) is a
//! *sweep*: a grid over delay model × k-policy × comm scheme × coding ×
//! seed, thousands of independent simulations. This module is the one
//! place that executes them:
//!
//! * [`RunSpec`] — one simulation: scenario axes + a fully materialised
//!   [`ExperimentConfig`](crate::config::ExperimentConfig) + its seed;
//! * [`SweepGrid`] — cartesian-product builder with per-axis labels
//!   (a new figure is a ~30-line grid declaration, not a bespoke loop);
//! * [`SweepExecutor`] — runs specs in parallel on
//!   [`exec::ThreadPool`](crate::exec::ThreadPool) and reassembles
//!   outputs in spec order;
//! * [`write_sweep_csv`] / [`sweep_meta`] — unified CSV emission through
//!   [`metrics::write_csv_with_header`](crate::metrics::write_csv_with_header),
//!   with the scenario axes as run-header meta lines.
//!
//! # Determinism contract
//!
//! `--jobs 1` and `--jobs N` are **byte-identical**: every spec's RNG
//! streams derive from its own `cfg.seed` (pinned at grid-build time,
//! see [`derive_seed`]), specs share no mutable state, and the executor
//! reorders completions back into spec order before anything downstream
//! sees them. Run order therefore cannot leak into results — the only
//! thing parallelism changes is wall-clock.
//! `rust/tests/test_sweep_equivalence.rs` asserts the contract across a
//! scenario grid.

mod executor;
mod spec;

pub use executor::{sweep_meta, write_sweep_csv, SweepExecutor};
pub use spec::{derive_seed, edit, CfgEdit, RunSpec, SweepGrid};
