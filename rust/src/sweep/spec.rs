//! Declarative sweep specifications.
//!
//! A [`RunSpec`] names one simulation — scenario axes, the fully
//! materialised [`ExperimentConfig`] (seed included), and a stable grid
//! index — and a [`SweepGrid`] expands cartesian products of config
//! edits into an ordered spec list. Everything stochastic about a run is
//! pinned *inside* its spec before execution starts, which is what lets
//! the executor run specs on any number of threads without the schedule
//! leaking into results.

use crate::config::ExperimentConfig;
use crate::rng::{Rng, SplitMix64};
use std::sync::Arc;

/// A config edit applied by one axis value (shared, so grid cells can
/// reuse it; must be pure — it sees a fresh clone of the base config).
pub type CfgEdit = Arc<dyn Fn(&mut ExperimentConfig) + Send + Sync>;

/// Wrap a closure as a [`CfgEdit`] (sugar for `SweepGrid::axis` call
/// sites, which would otherwise spell out the `Arc<dyn Fn…>` cast).
pub fn edit<F>(f: F) -> CfgEdit
where
    F: Fn(&mut ExperimentConfig) + Send + Sync + 'static,
{
    Arc::new(f)
}

/// Derive the RNG seed of one spec from a sweep's base seed and the
/// spec's grid index, via a SplitMix64 hash.
///
/// This is the sweep layer's determinism rule: every spec owns a seed
/// that is a pure function of `(base_seed, index)` — specs never share a
/// mutable RNG, so neither the worker count nor the completion order can
/// reach any random stream, and `--jobs 1` ≡ `--jobs N` bit for bit.
pub fn derive_seed(base_seed: u64, index: u64) -> u64 {
    let mut mix = SplitMix64::new(base_seed);
    let expanded = mix.next_u64();
    let mut mix = SplitMix64::new(expanded ^ index);
    mix.next_u64()
}

/// One fully-materialised experiment in a sweep.
#[derive(Clone)]
pub struct RunSpec {
    /// Stable position in the sweep (defines output and CSV order).
    pub index: usize,
    /// Joined axis-value label (e.g. `"topk10/k=40"`); equals
    /// `cfg.label`.
    pub label: String,
    /// `(axis name, value label)` pairs, outermost axis first (empty for
    /// hand-built specs).
    pub axes: Vec<(String, String)>,
    /// The concrete experiment to run.
    pub cfg: ExperimentConfig,
}

impl RunSpec {
    /// Wrap a hand-built config as a one-off spec (no grid axes); the
    /// spec label is the config's label.
    pub fn from_config(index: usize, cfg: ExperimentConfig) -> Self {
        Self { index, label: cfg.label.clone(), axes: Vec::new(), cfg }
    }

    /// Run-header meta line for the sweep CSV: the scenario axes and
    /// seed that produced this series. The RNG seed is spelled
    /// `rng_seed=` so it can never collide with a sweep axis named
    /// `seed`.
    pub fn meta_line(&self) -> String {
        let mut line = format!("run {}:", self.label);
        for (axis, value) in &self.axes {
            line.push_str(&format!(" {axis}={value}"));
        }
        line.push_str(&format!(" rng_seed={}", self.cfg.seed));
        line
    }
}

/// One value of a sweep axis: a display label plus the config edit that
/// realises it.
struct AxisValue {
    label: String,
    edit: CfgEdit,
}

/// One sweep axis: a name plus its values.
struct Axis {
    name: String,
    values: Vec<AxisValue>,
}

/// Cartesian-product builder over a base config.
///
/// Axes multiply: the first axis added varies slowest (row-major order),
/// so `axis A × axis B` enumerates `a0/b0, a0/b1, …, a1/b0, …`. Each
/// cell clones the base config and applies one edit per axis; the cell
/// label is the `/`-joined value labels.
pub struct SweepGrid {
    base: ExperimentConfig,
    axes: Vec<Axis>,
    reseed: Option<u64>,
}

impl SweepGrid {
    /// Start a grid from the shared base config.
    pub fn new(base: ExperimentConfig) -> Self {
        Self { base, axes: Vec::new(), reseed: None }
    }

    /// Add an axis from `(value label, edit)` pairs (see [`edit`]).
    pub fn axis(
        mut self,
        name: impl Into<String>,
        values: Vec<(String, CfgEdit)>,
    ) -> Self {
        self.axes.push(Axis {
            name: name.into(),
            values: values
                .into_iter()
                .map(|(label, edit)| AxisValue { label, edit })
                .collect(),
        });
        self
    }

    /// Add an axis by mapping a shared `(label, apply)` pair over a list
    /// of items — convenient for numeric axes like `k ∈ {10, 20, 40}`.
    pub fn axis_over<T, L, F>(
        self,
        name: impl Into<String>,
        items: Vec<T>,
        label: L,
        apply: F,
    ) -> Self
    where
        T: Send + Sync + 'static,
        L: Fn(&T) -> String,
        F: Fn(&T, &mut ExperimentConfig) + Send + Sync + 'static,
    {
        let apply = Arc::new(apply);
        let values = items
            .into_iter()
            .map(|item| {
                let text = label(&item);
                let apply = Arc::clone(&apply);
                let cell: CfgEdit = Arc::new(move |cfg: &mut ExperimentConfig| {
                    apply(&item, cfg)
                });
                (text, cell)
            })
            .collect();
        self.axis(name, values)
    }

    /// Add a repetition axis: `reps` copies of every cell, each with an
    /// independent RNG stream `derive_seed(base_seed, rep)` (see
    /// [`derive_seed`] for why seeds are derived, never shared).
    pub fn repeats(self, reps: usize, base_seed: u64) -> Self {
        self.axis_over(
            "rep",
            (0..reps as u64).collect(),
            |r| format!("rep{r}"),
            move |r, cfg| cfg.seed = derive_seed(base_seed, *r),
        )
    }

    /// Re-seed every cell from its grid *index* after the axis edits
    /// run: `seed = derive_seed(base_seed, index)`. Use when the axes
    /// themselves don't manage seeds and each cell should still draw an
    /// independent stream.
    pub fn with_derived_seeds(mut self, base_seed: u64) -> Self {
        self.reseed = Some(base_seed);
        self
    }

    /// Number of cells the grid will expand to.
    pub fn len(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }

    /// True when some axis has no values (the grid expands to nothing).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the cartesian product into ordered [`RunSpec`]s.
    pub fn build(&self) -> Vec<RunSpec> {
        let total = self.len();
        let mut specs = Vec::with_capacity(total);
        for index in 0..total {
            let mut cfg = self.base.clone();
            let mut labels = Vec::with_capacity(self.axes.len());
            let mut axes = Vec::with_capacity(self.axes.len());
            let mut rem = index;
            let mut stride = total;
            for axis in &self.axes {
                stride /= axis.values.len();
                let value = &axis.values[rem / stride];
                rem %= stride;
                (value.edit)(&mut cfg);
                labels.push(value.label.clone());
                axes.push((axis.name.clone(), value.label.clone()));
            }
            let label = if labels.is_empty() {
                cfg.label.clone()
            } else {
                labels.join("/")
            };
            cfg.label = label.clone();
            if let Some(base_seed) = self.reseed {
                cfg.seed = derive_seed(base_seed, index as u64);
            }
            specs.push(RunSpec { index, label, axes, cfg });
        }
        specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicySpec;

    fn base() -> ExperimentConfig {
        ExperimentConfig {
            label: "base".into(),
            n: 10,
            max_iterations: 50,
            max_time: 0.0,
            workload: crate::config::WorkloadSpec::LinReg { m: 200, d: 10 },
            ..Default::default()
        }
    }

    #[test]
    fn grid_is_row_major_and_labelled() {
        let specs = SweepGrid::new(base())
            .axis_over(
                "k",
                vec![2usize, 5],
                |k| format!("k={k}"),
                |k, cfg| cfg.policy = PolicySpec::Fixed { k: *k },
            )
            .axis(
                "seed",
                vec![
                    ("s0".to_string(), edit(|c| c.seed = 0)),
                    ("s1".to_string(), edit(|c| c.seed = 1)),
                ],
            )
            .build();
        assert_eq!(specs.len(), 4);
        let labels: Vec<&str> =
            specs.iter().map(|s| s.label.as_str()).collect();
        assert_eq!(labels, ["k=2/s0", "k=2/s1", "k=5/s0", "k=5/s1"]);
        assert_eq!(specs[2].cfg.policy, PolicySpec::Fixed { k: 5 });
        assert_eq!(specs[3].cfg.seed, 1);
        assert_eq!(specs[3].index, 3);
        assert_eq!(
            specs[3].axes,
            vec![
                ("k".to_string(), "k=5".to_string()),
                ("seed".to_string(), "s1".to_string())
            ]
        );
        assert_eq!(
            specs[0].meta_line(),
            "run k=2/s0: k=k=2 seed=s0 rng_seed=0"
        );
    }

    #[test]
    fn axisless_grid_is_the_base_config() {
        let specs = SweepGrid::new(base()).build();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].label, "base");
        assert!(specs[0].axes.is_empty());
    }

    #[test]
    fn empty_axis_expands_to_nothing() {
        let grid = SweepGrid::new(base()).axis("empty", Vec::new());
        assert!(grid.is_empty());
        assert!(grid.build().is_empty());
    }

    #[test]
    fn derived_seeds_are_stable_and_distinct() {
        let a = derive_seed(7, 0);
        assert_eq!(a, derive_seed(7, 0), "pure function of (base, index)");
        let seeds: Vec<u64> = (0..64).map(|i| derive_seed(7, i)).collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len(), "no collisions in a sweep");
        assert_ne!(derive_seed(7, 3), derive_seed(8, 3), "base matters");
    }

    #[test]
    fn repeats_axis_derives_per_rep_seeds() {
        let specs =
            SweepGrid::new(base()).repeats(3, 99).build();
        assert_eq!(specs.len(), 3);
        let seeds: Vec<u64> = specs.iter().map(|s| s.cfg.seed).collect();
        assert_eq!(seeds[0], derive_seed(99, 0));
        assert_eq!(seeds[2], derive_seed(99, 2));
        assert_eq!(specs[1].label, "rep1");
    }

    #[test]
    fn with_derived_seeds_reseeds_by_cell_index() {
        let specs = SweepGrid::new(base())
            .axis_over(
                "k",
                vec![2usize, 5],
                |k| format!("k={k}"),
                |k, cfg| cfg.policy = PolicySpec::Fixed { k: *k },
            )
            .with_derived_seeds(42)
            .build();
        assert_eq!(specs[0].cfg.seed, derive_seed(42, 0));
        assert_eq!(specs[1].cfg.seed, derive_seed(42, 1));
    }
}
