//! Lemma 1 — error of fastest-k SGD vs *wall-clock time*.
//!
//! With high probability for large t (Eq. 3 of the paper, constant error
//! term ε dropped exactly as in the paper's analysis):
//!
//! ```text
//! E[F(w_t) − F*]  ≤  ηLσ²/(2cks)  +  (1 − ηc)^{t/μ_k} · (E₀ − ηLσ²/(2cks))
//! ```
//!
//! where `μ_k = E[X_(k)]` converts iterations to time (renewal reward),
//! and the first term is the *error floor* of waiting for only k workers.

use crate::stats::OrderStats;

/// System parameters of Proposition 1 / Lemma 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoundParams {
    /// Step size η (must satisfy ηc < 1).
    pub eta: f64,
    /// Lipschitz constant L of ∇F.
    pub l: f64,
    /// Strong-convexity constant c.
    pub c: f64,
    /// Gradient-variance bound σ².
    pub sigma2: f64,
    /// Rows per shard s = m/n.
    pub s: usize,
    /// Initial sub-optimality F(w₀) − F*.
    pub f0_err: f64,
}

impl BoundParams {
    /// Paper Example 1 parameter set (n = 5 companion: see `OrderStats`).
    pub fn example1() -> Self {
        Self { eta: 0.001, l: 2.0, c: 1.0, sigma2: 10.0, s: 10, f0_err: 100.0 }
    }

    /// Validate the standing assumptions (ηc < 1, positivity).
    pub fn validate(&self) -> Result<(), String> {
        if self.eta <= 0.0 || self.l <= 0.0 || self.c <= 0.0 {
            return Err("eta, L, c must be positive".into());
        }
        if self.eta * self.c >= 1.0 {
            return Err(format!(
                "need eta*c < 1 (got {})",
                self.eta * self.c
            ));
        }
        if self.sigma2 < 0.0 || self.f0_err < 0.0 {
            return Err("sigma2 and f0_err must be non-negative".into());
        }
        if self.s == 0 {
            return Err("s must be >= 1".into());
        }
        Ok(())
    }
}

/// The Lemma-1 bound, specialized to a delay model via its order-statistic
/// table.
#[derive(Debug, Clone)]
pub struct ErrorBound {
    params: BoundParams,
    order: OrderStats,
}

impl ErrorBound {
    /// Couple bound parameters with the delay model's order statistics.
    pub fn new(params: BoundParams, order: OrderStats) -> Self {
        params.validate().expect("invalid bound parameters");
        Self { params, order }
    }

    /// Borrow the parameters.
    pub fn params(&self) -> &BoundParams {
        &self.params
    }

    /// Borrow the order-statistic table.
    pub fn order(&self) -> &OrderStats {
        &self.order
    }

    /// The stationary error floor `ηLσ²/(2cks)` for a given k.
    pub fn floor(&self, k: usize) -> f64 {
        let p = &self.params;
        p.eta * p.l * p.sigma2 / (2.0 * p.c * k as f64 * p.s as f64)
    }

    /// `μ_k = E[X_(k)]`.
    pub fn mu(&self, k: usize) -> f64 {
        self.order.mean(k)
    }

    /// Evaluate the bound at time `t ≥ t0`, running with k, having started
    /// at error `e0` at time `t0` (Eq. 3 with the renewal clock shifted).
    pub fn eval_from(&self, k: usize, t: f64, t0: f64, e0: f64) -> f64 {
        assert!(t >= t0, "t must be >= t0");
        let rho = 1.0 - self.params.eta * self.params.c;
        let fl = self.floor(k);
        fl + rho.powf((t - t0) / self.mu(k)) * (e0 - fl)
    }

    /// Evaluate the bound from the start (t0 = 0, e0 = F(w₀) − F*).
    pub fn eval(&self, k: usize, t: f64) -> f64 {
        self.eval_from(k, t, 0.0, self.params.f0_err)
    }

    /// The high-probability failure bound of Lemma 1:
    /// `σ_k²/ε² · (2/(t μ_k) + 1/t²)` — how loose the w.h.p. claim is at t.
    pub fn failure_prob(&self, k: usize, t: f64, eps: f64) -> f64 {
        let var = self.order.var(k);
        (var / (eps * eps)) * (2.0 / (t * self.mu(k)) + 1.0 / (t * t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example1_bound() -> ErrorBound {
        // X_i ~ exp(mu) with mu=5 per Example 1; μ_k = (H_n − H_{n−k})/5.
        ErrorBound::new(BoundParams::example1(), OrderStats::exponential(5, 5.0))
    }

    #[test]
    fn bound_starts_at_f0() {
        let b = example1_bound();
        for k in 1..=5 {
            assert!((b.eval(k, 0.0) - 100.0).abs() < 1e-9);
        }
    }

    #[test]
    fn bound_decreases_to_floor() {
        let b = example1_bound();
        for k in 1..=5 {
            let fl = b.floor(k);
            let huge = b.eval(k, 1e7);
            assert!((huge - fl).abs() < 1e-9, "k={k}");
            // Monotone decreasing in t.
            let mut prev = f64::INFINITY;
            for i in 0..50 {
                let v = b.eval(k, i as f64 * 100.0);
                assert!(v <= prev + 1e-12);
                prev = v;
            }
        }
    }

    #[test]
    fn floor_is_decreasing_in_k() {
        let b = example1_bound();
        for k in 2..=5 {
            assert!(b.floor(k) < b.floor(k - 1));
        }
        // Explicit Example-1 value: floor(1) = ηLσ²/(2cs) = 0.001*2*10/20.
        assert!((b.floor(1) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn small_k_decreases_faster_initially() {
        let b = example1_bound();
        // Early on, k=1 has the smallest bound (fastest iterations).
        let t = 5.0;
        let v1 = b.eval(1, t);
        let v5 = b.eval(5, t);
        assert!(v1 < v5, "early: k=1 {v1} should beat k=5 {v5}");
        // Late, k=5 wins (lowest floor).
        let t = 1e5;
        assert!(b.eval(5, t) < b.eval(1, t));
    }

    #[test]
    fn eval_from_chains_consistently() {
        let b = example1_bound();
        // Evaluating 0→t1→t2 with the same k equals evaluating 0→t2.
        let (t1, t2) = (50.0, 120.0);
        let e1 = b.eval(3, t1);
        let chained = b.eval_from(3, t2, t1, e1);
        let direct = b.eval(3, t2);
        assert!((chained - direct).abs() < 1e-9);
    }

    #[test]
    fn failure_prob_decays_in_t() {
        let b = example1_bound();
        assert!(b.failure_prob(3, 1000.0, 0.1) < b.failure_prob(3, 100.0, 0.1));
    }

    #[test]
    fn validate_catches_bad_params() {
        let mut p = BoundParams::example1();
        p.eta = 2.0; // eta*c = 2 >= 1
        assert!(p.validate().is_err());
        let mut p2 = BoundParams::example1();
        p2.s = 0;
        assert!(p2.validate().is_err());
    }
}
