//! Theoretical machinery: the Lemma-1 error bound and the Theorem-1
//! bound-optimal switching times.
//!
//! Everything needed to regenerate Fig. 1 / Example 1, and to drive the
//! [`BoundOptimal`](crate::policy::BoundOptimal) oracle policy.

mod bound;
mod switching;

pub use bound::{BoundParams, ErrorBound};
pub use switching::{adaptive_envelope, switching_times, SwitchPoint};
