//! Theorem 1 — the bound-optimal switching times.
//!
//! Starting from k = 1 at t₀ = 0, the bound-optimal time to switch from
//! waiting-for-k to waiting-for-(k+1) is (paper, Theorem 1):
//!
//! ```text
//! t_k = t_{k−1} + μ_k/(−ln(1−ηc)) · [ ln(μ_{k+1} − μ_k) − ln(ηLσ²μ_k)
//!        + ln(2ck(k+1)s·E(t_{k−1}) − ηL(k+1)σ²) ]
//! ```
//!
//! where `E(t_{k−1})` is the bound value at the previous switch. The
//! bracket is `ln` of
//! `(μ_{k+1} − μ_k) · k(k+1) · (E(t_{k−1}) − floor_k) / (floor(1)·k·μ_k)`
//! — equivalently, the switch happens exactly when the *instantaneous
//! decrease rates* of the k and k+1 curves coincide:
//! `(E − floor_k)/μ_k = (E − floor_{k+1})/μ_{k+1}` (verified in tests).

use super::{ErrorBound};

/// One switch: at `time`, move to `k_next`, with the bound value there.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwitchPoint {
    /// Wall-clock time of the switch t_k.
    pub time: f64,
    /// k after the switch (= k+1).
    pub k_next: usize,
    /// Bound value E(t_k) at the switch.
    pub error: f64,
}

/// Compute the Theorem-1 switching times t_1 … t_{n−1}.
///
/// If the bracket's argument is ≤ 1 for some k (meaning staying with k is
/// never better), the switch time collapses to the previous one (`dt = 0`).
pub fn switching_times(bound: &ErrorBound) -> Vec<SwitchPoint> {
    let n = bound.order().n();
    let p = *bound.params();
    let rho = 1.0 - p.eta * p.c;
    let neg_ln_rho = -rho.ln();

    let mut out = Vec::with_capacity(n - 1);
    let mut t_prev = 0.0;
    let mut e_prev = p.f0_err;
    for k in 1..n {
        let mu_k = bound.mu(k);
        let mu_k1 = bound.mu(k + 1);
        let kf = k as f64;
        // Theorem-1 bracket, verbatim from the paper.
        let lead = 2.0 * p.c * kf * (kf + 1.0) * p.s as f64 * e_prev
            - p.eta * p.l * (kf + 1.0) * p.sigma2;
        let dt = if lead <= 0.0 {
            // Already below the crossing error: switch immediately.
            0.0
        } else {
            let bracket =
                (mu_k1 - mu_k).ln() - (p.eta * p.l * p.sigma2 * mu_k).ln()
                    + lead.ln();
            (mu_k / neg_ln_rho * bracket).max(0.0)
        };
        let t_k = t_prev + dt;
        let e_k = bound.eval_from(k, t_k, t_prev, e_prev);
        out.push(SwitchPoint { time: t_k, k_next: k + 1, error: e_k });
        t_prev = t_k;
        e_prev = e_k;
    }
    out
}

/// The adaptive bound envelope of Fig. 1: evaluate the piecewise bound
/// that runs k = 1 on `[0, t_1)`, k = 2 on `[t_1, t_2)`, … at each query
/// time in `ts`.
pub fn adaptive_envelope(bound: &ErrorBound, ts: &[f64]) -> Vec<f64> {
    let switches = switching_times(bound);
    let p = *bound.params();
    ts.iter()
        .map(|&t| {
            // Find the active segment.
            let mut k = 1usize;
            let mut t0 = 0.0;
            let mut e0 = p.f0_err;
            for sw in &switches {
                if t < sw.time {
                    break;
                }
                k = sw.k_next;
                t0 = sw.time;
                e0 = sw.error;
            }
            bound.eval_from(k, t, t0, e0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::OrderStats;
    use crate::theory::BoundParams;

    fn example1() -> ErrorBound {
        ErrorBound::new(BoundParams::example1(), OrderStats::exponential(5, 5.0))
    }

    #[test]
    fn times_are_nondecreasing() {
        let sw = switching_times(&example1());
        assert_eq!(sw.len(), 4);
        for w in sw.windows(2) {
            assert!(w[1].time >= w[0].time, "{sw:?}");
        }
        assert!(sw[0].time > 0.0, "first switch should be after a transient");
    }

    #[test]
    fn errors_decrease_along_switches() {
        let sw = switching_times(&example1());
        for w in sw.windows(2) {
            assert!(w[1].error < w[0].error, "{sw:?}");
        }
    }

    #[test]
    fn switch_matches_rate_equalization() {
        // At t_k the decrease rates of curves k and k+1 must coincide:
        // (E − floor_k)/μ_k = (E − floor_{k+1})/μ_{k+1}.
        let b = example1();
        let sw = switching_times(&b);
        for (idx, s) in sw.iter().enumerate() {
            let k = idx + 1;
            if s.time == 0.0 {
                continue;
            }
            let lhs = (s.error - b.floor(k)) / b.mu(k);
            let rhs = (s.error - b.floor(k + 1)) / b.mu(k + 1);
            let rel = (lhs - rhs).abs() / lhs.abs().max(1e-300);
            assert!(rel < 1e-6, "k={k}: lhs={lhs} rhs={rhs}");
        }
    }

    #[test]
    fn envelope_is_min_like() {
        // The envelope must never exceed the best fixed-k bound by more
        // than numerical slack *after its own switch point*, and must beat
        // every fixed-k curve somewhere.
        let b = example1();
        let ts: Vec<f64> = (0..2000).map(|i| i as f64 * 10.0).collect();
        let env = adaptive_envelope(&b, &ts);
        // Envelope starts at f0.
        assert!((env[0] - 100.0).abs() < 1e-9);
        // Envelope is (weakly) decreasing.
        for w in env.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
        // At the far end the envelope reaches (near) the k=5 floor,
        // which no fixed k < 5 can.
        let last = *env.last().unwrap();
        assert!(last < b.floor(4), "end value {last} vs floor4 {}", b.floor(4));
    }

    #[test]
    fn envelope_tracks_k1_early() {
        let b = example1();
        let sw = switching_times(&b);
        let t_probe = sw[0].time * 0.5;
        let env = adaptive_envelope(&b, &[t_probe]);
        assert!((env[0] - b.eval(1, t_probe)).abs() < 1e-9);
    }

    #[test]
    fn immediate_switch_when_f0_below_crossing() {
        // Tiny initial error: every crossing error exceeds it, so all
        // switches collapse to t = 0 — adaptive == fastest-n from the start.
        let params = BoundParams { f0_err: 1e-9, ..BoundParams::example1() };
        let b = ErrorBound::new(params, OrderStats::exponential(5, 5.0));
        let sw = switching_times(&b);
        for s in &sw {
            assert_eq!(s.time, 0.0, "{sw:?}");
        }
    }
}
