//! Post-hoc analysis of recorded traces (`trace analyze` in the CLI).
//!
//! Everything here is computed from the event stream alone — no replay,
//! no model, no RNG: per-worker utilization (arXiv:2304.08589 needs it
//! to reason about load assignment), ingress queueing delay, staleness
//! histograms (the per-round decompositions the error–runtime analysis
//! of Dutta et al., arXiv:1803.01113, hinges on), and the per-round
//! wait-time split between compute, upload, and download.

use super::{Event, Trace};

/// One worker's aggregate activity in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerUse {
    /// Worker index.
    pub worker: usize,
    /// Number of compute responses sampled for this worker.
    pub responses: u64,
    /// Total sampled compute time (scaled share, excludes transfers).
    pub busy: f64,
    /// `busy / makespan`. Round disciplines sample every worker every
    /// round but keep only the fastest k, so a straggler's utilization
    /// counts work the round discarded and can exceed 1.
    pub utilization: f64,
}

/// Aggregate statistics of one trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceAnalysis {
    /// Largest clock value in the stream (end of the recorded run).
    pub makespan: f64,
    /// Number of gradient applies (rounds, or async updates).
    pub applies: u64,
    /// Per-worker activity, indexed by worker.
    pub per_worker: Vec<WorkerUse>,
    /// `staleness_hist[s]` = applies whose gradient was `s` versions
    /// stale (round disciplines apply fresh gradients: all mass at 0).
    pub staleness_hist: Vec<u64>,
    /// Mean staleness over all applies.
    pub mean_staleness: f64,
    /// Arrivals served by the shared ingress (0 without an ingress).
    pub ingress_served: u64,
    /// Mean sojourn (queueing + service) at the ingress.
    pub ingress_wait_mean: f64,
    /// Worst-case sojourn at the ingress.
    pub ingress_wait_max: f64,
    /// Total sampled compute time across all workers.
    pub compute_total: f64,
    /// Total sampled uplink transfer time.
    pub upload_total: f64,
    /// Total sampled downlink transfer time.
    pub download_total: f64,
    /// Adaptive k-change decisions `(step, time, new k)`.
    pub k_changes: Vec<(u64, f64, u32)>,
}

impl TraceAnalysis {
    /// Compute every statistic in one pass over the events.
    pub fn from_trace(trace: &Trace) -> Self {
        let n = trace.n_workers as usize;
        let mut per = vec![(0u64, 0.0f64); n];
        let mut makespan = 0.0f64;
        let mut applies = 0u64;
        let mut staleness_hist: Vec<u64> = Vec::new();
        let mut staleness_sum = 0u64;
        let mut ingress_served = 0u64;
        let mut ingress_wait_sum = 0.0;
        let mut ingress_wait_max = 0.0f64;
        let (mut compute_total, mut upload_total, mut download_total) =
            (0.0, 0.0, 0.0);
        let mut k_changes = Vec::new();
        for ev in &trace.events {
            match *ev {
                Event::Broadcast { time, .. } => makespan = makespan.max(time),
                Event::Compute {
                    worker, compute, upload, download, ..
                } => {
                    if let Some(p) = per.get_mut(worker as usize) {
                        p.0 += 1;
                        p.1 += compute;
                    }
                    compute_total += compute;
                    upload_total += upload;
                    download_total += download;
                }
                Event::IngressServe { arrival, served, .. } => {
                    let wait = served - arrival;
                    ingress_served += 1;
                    ingress_wait_sum += wait;
                    ingress_wait_max = ingress_wait_max.max(wait);
                    makespan = makespan.max(served);
                }
                Event::Apply { time, staleness, .. } => {
                    applies += 1;
                    staleness_sum += staleness;
                    let s = staleness as usize;
                    if staleness_hist.len() <= s {
                        staleness_hist.resize(s + 1, 0);
                    }
                    staleness_hist[s] += 1;
                    makespan = makespan.max(time);
                }
                Event::KChange { step, time, k } => {
                    k_changes.push((step, time, k));
                    makespan = makespan.max(time);
                }
                Event::Sample { time, .. } => {
                    if time.is_finite() {
                        makespan = makespan.max(time);
                    }
                }
                Event::Transmit { .. } | Event::Push { .. } => {}
            }
        }
        let per_worker = per
            .into_iter()
            .enumerate()
            .map(|(worker, (responses, busy))| WorkerUse {
                worker,
                responses,
                busy,
                utilization: if makespan > 0.0 { busy / makespan } else { 0.0 },
            })
            .collect();
        Self {
            makespan,
            applies,
            per_worker,
            staleness_hist,
            mean_staleness: if applies > 0 {
                staleness_sum as f64 / applies as f64
            } else {
                0.0
            },
            ingress_served,
            ingress_wait_mean: if ingress_served > 0 {
                ingress_wait_sum / ingress_served as f64
            } else {
                0.0
            },
            ingress_wait_max,
            compute_total,
            upload_total,
            download_total,
            k_changes,
        }
    }

    /// Multi-section plain-text report.
    pub fn report(&self, trace: &Trace) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace analysis: {} ({} workers, {} events)\n",
            trace.label,
            trace.n_workers,
            trace.events.len()
        ));
        out.push_str(&format!(
            "  discipline {} | makespan {:.6} | {} applies | {} k-changes\n",
            trace.discipline,
            self.makespan,
            self.applies,
            self.k_changes.len()
        ));
        out.push_str("\nper-round wait decomposition (mean per apply):\n");
        let denom = self.applies.max(1) as f64;
        out.push_str(&format!(
            "  compute {:.6} | upload {:.6} | download {:.6}\n",
            self.compute_total / denom,
            self.upload_total / denom,
            self.download_total / denom
        ));
        out.push_str("\nworker utilization (sampled compute / makespan):\n");
        for w in &self.per_worker {
            out.push_str(&format!(
                "  w{:<3} responses={:<6} busy={:<12.6} util={:.3}\n",
                w.worker, w.responses, w.busy, w.utilization
            ));
        }
        if self.ingress_served > 0 {
            out.push_str(&format!(
                "\ningress: {} served | sojourn mean {:.6} max {:.6}\n",
                self.ingress_served,
                self.ingress_wait_mean,
                self.ingress_wait_max
            ));
        }
        out.push_str(&format!(
            "\nstaleness: mean {:.3}\n",
            self.mean_staleness
        ));
        for (s, count) in
            self.staleness_hist.iter().enumerate().filter(|(_, &c)| c > 0)
        {
            out.push_str(&format!("  s={s:<3} {count}\n"));
        }
        if !self.k_changes.is_empty() {
            out.push_str("\nk-changes:\n");
            for (step, time, k) in &self.k_changes {
                out.push_str(&format!(
                    "  step={step} t={time:.6} k->{k}\n"
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::Discipline;
    use super::*;

    fn toy_trace() -> Trace {
        let mut t = Trace::new(Discipline::Async, 2, "toy");
        t.push(Event::Compute {
            iteration: 0,
            worker: 0,
            raw: 1.0,
            compute: 1.0,
            upload: 0.5,
            download: 0.25,
        });
        t.push(Event::Compute {
            iteration: 0,
            worker: 1,
            raw: 3.0,
            compute: 3.0,
            upload: 0.5,
            download: 0.25,
        });
        t.push(Event::IngressServe { worker: 0, arrival: 1.0, served: 1.5 });
        t.push(Event::Apply { step: 1, time: 1.5, k: 1, staleness: 0 });
        t.push(Event::IngressServe { worker: 1, arrival: 3.0, served: 4.5 });
        t.push(Event::Apply { step: 2, time: 4.5, k: 1, staleness: 2 });
        t.push(Event::KChange { step: 2, time: 4.5, k: 3 });
        t
    }

    #[test]
    fn one_pass_statistics_are_exact() {
        let t = toy_trace();
        let a = TraceAnalysis::from_trace(&t);
        assert_eq!(a.makespan, 4.5);
        assert_eq!(a.applies, 2);
        assert_eq!(a.mean_staleness, 1.0);
        assert_eq!(a.staleness_hist, vec![1, 0, 1]);
        assert_eq!(a.ingress_served, 2);
        assert_eq!(a.ingress_wait_mean, 1.0);
        assert_eq!(a.ingress_wait_max, 1.5);
        assert_eq!(a.per_worker.len(), 2);
        assert_eq!(a.per_worker[1].busy, 3.0);
        assert_eq!(a.per_worker[1].utilization, 3.0 / 4.5);
        assert_eq!(a.compute_total, 4.0);
        assert_eq!(a.upload_total, 1.0);
        assert_eq!(a.k_changes, vec![(2, 4.5, 3)]);
    }

    #[test]
    fn empty_trace_analyzes_to_zeroes() {
        let t = Trace::new(Discipline::Sync, 3, "empty");
        let a = TraceAnalysis::from_trace(&t);
        assert_eq!(a.makespan, 0.0);
        assert_eq!(a.applies, 0);
        assert_eq!(a.mean_staleness, 0.0);
        assert_eq!(a.per_worker.len(), 3);
        assert_eq!(a.per_worker[0].utilization, 0.0);
    }

    #[test]
    fn report_names_every_section() {
        let t = toy_trace();
        let rep = TraceAnalysis::from_trace(&t).report(&t);
        for needle in [
            "trace analysis",
            "wait decomposition",
            "worker utilization",
            "ingress",
            "staleness",
            "k-changes",
        ] {
            assert!(rep.contains(needle), "missing {needle:?} in:\n{rep}");
        }
    }
}
