//! Human-readable rendering of traces (`trace dump` in the CLI).

use super::{Event, Trace};
use std::fmt;

impl fmt::Display for Event {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Event::Broadcast { step, time, bytes } => write!(
                f,
                "broadcast  step={step} t={time:.6} bytes={bytes}"
            ),
            Event::Compute {
                iteration,
                worker,
                raw,
                compute,
                upload,
                download,
            } => write!(
                f,
                "compute    it={iteration} w={worker} raw={raw:.6} \
                 compute={compute:.6} up={upload:.6} down={download:.6}"
            ),
            Event::Transmit { step, worker, bytes } => write!(
                f,
                "transmit   step={step} w={worker} bytes={bytes}"
            ),
            Event::IngressServe { worker, arrival, served } => write!(
                f,
                "ingress    w={worker} arrival={arrival:.6} \
                 served={served:.6} wait={:.6}",
                served - arrival
            ),
            Event::Apply { step, time, k, staleness } => write!(
                f,
                "apply      step={step} t={time:.6} k={k} \
                 staleness={staleness}"
            ),
            Event::KChange { step, time, k } => {
                write!(f, "k-change   step={step} t={time:.6} k->{k}")
            }
            Event::Push { step, worker, bytes, delay } => write!(
                f,
                "push       step={step} w={worker} bytes={bytes} \
                 delay={delay:.6}"
            ),
            Event::Sample {
                iteration,
                time,
                k,
                error,
                bytes,
                ..
            } => write!(
                f,
                "sample     it={iteration} t={time:.6} k={k} \
                 error={error:.6e} bytes={bytes}"
            ),
        }
    }
}

impl Trace {
    /// Multi-line dump: header line, then up to `limit` events (all when
    /// `None`), then an elision count if events were cut.
    pub fn dump(&self, limit: Option<usize>) -> String {
        let mut out = format!(
            "trace: discipline={} workers={} label={:?} events={}\n",
            self.discipline,
            self.n_workers,
            self.label,
            self.events.len()
        );
        let shown = limit.unwrap_or(self.events.len()).min(self.events.len());
        for ev in &self.events[..shown] {
            out.push_str(&format!("  {ev}\n"));
        }
        if shown < self.events.len() {
            out.push_str(&format!(
                "  ... {} more event(s)\n",
                self.events.len() - shown
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::Discipline;
    use super::*;

    #[test]
    fn dump_honours_limit_and_reports_elision() {
        let mut t = Trace::new(Discipline::Sync, 2, "d");
        for j in 0..5 {
            t.push(Event::KChange { step: j, time: j as f64, k: 1 });
        }
        let full = t.dump(None);
        assert_eq!(full.lines().count(), 6);
        assert!(full.starts_with("trace: discipline=sync workers=2"));
        let cut = t.dump(Some(2));
        assert_eq!(cut.lines().count(), 4);
        assert!(cut.contains("... 3 more event(s)"), "{cut}");
    }

    #[test]
    fn event_lines_name_their_kind() {
        let ev = Event::IngressServe { worker: 3, arrival: 1.0, served: 1.5 };
        let line = ev.to_string();
        assert!(line.contains("ingress"), "{line}");
        assert!(line.contains("wait=0.5"), "{line}");
    }
}
