//! The event vocabulary and its per-kind binary codec.
//!
//! Each variant maps to one frame kind with a fixed little-endian
//! payload. Frame layout (kind byte + payload length byte + payload) is
//! defined in `format.rs`; this module owns what goes *inside* the
//! payload. Times are stored as raw `f64` bit patterns so decode is the
//! exact inverse of encode.

use super::format::Cursor;
use super::reader::TraceError;

/// Frame kind tags. Kind 0 is reserved (never written) so a zeroed
/// buffer cannot parse as a valid frame stream.
pub(super) const KIND_BROADCAST: u8 = 1;
pub(super) const KIND_COMPUTE: u8 = 2;
pub(super) const KIND_TRANSMIT: u8 = 3;
pub(super) const KIND_INGRESS: u8 = 4;
pub(super) const KIND_APPLY: u8 = 5;
pub(super) const KIND_KCHANGE: u8 = 6;
pub(super) const KIND_PUSH: u8 = 7;
pub(super) const KIND_SAMPLE: u8 = 8;

/// One engine event.
///
/// Step/iteration indexing follows the emitting discipline: round
/// disciplines (sync, coded, threaded) use the round index `j` (the
/// engine's pre-increment step counter), the async disciplines use the
/// global update counter. `Compute.iteration` is always the key the
/// delay model was sampled with, which is what makes replay exact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// Model broadcast to all workers at the start of a round.
    Broadcast {
        /// Round index at broadcast time.
        step: u64,
        /// Virtual clock at broadcast time.
        time: f64,
        /// Downlink bytes charged for the broadcast.
        bytes: u64,
    },
    /// One worker's sampled compute-plus-comm response.
    Compute {
        /// Delay-model iteration key (round index, or async cycle key).
        iteration: u64,
        /// Worker index.
        worker: u32,
        /// Raw delay-model draw, *before* any pricing — the replay key.
        raw: f64,
        /// Compute share after scaling (equals `raw` for uncoded runs).
        compute: f64,
        /// Uplink transfer share.
        upload: f64,
        /// Downlink transfer share.
        download: f64,
    },
    /// Accepted uplink gradient message.
    Transmit {
        /// Engine step counter at acceptance.
        step: u64,
        /// Sending worker.
        worker: u32,
        /// Message size on the wire.
        bytes: u64,
    },
    /// Shared-ingress service of one arrival.
    IngressServe {
        /// Worker whose message was served.
        worker: u32,
        /// Arrival time at the master.
        arrival: f64,
        /// Service-completion time (arrival + queueing + service).
        served: f64,
    },
    /// Gradient applied to the model.
    Apply {
        /// Round index (sync/coded/threaded) or update index (async).
        step: u64,
        /// Virtual clock at the apply.
        time: f64,
        /// Number of gradients in the apply (k for rounds, 1 async).
        k: u32,
        /// Staleness of the applied gradient (0 for round disciplines).
        staleness: u64,
    },
    /// Adaptive policy changed k.
    KChange {
        /// Round index of the decision.
        step: u64,
        /// Virtual clock at the decision.
        time: f64,
        /// New k (takes effect next round).
        k: u32,
    },
    /// Model pushed to one worker (async downlink).
    Push {
        /// Engine step counter at the push.
        step: u64,
        /// Receiving worker.
        worker: u32,
        /// Downlink bytes charged.
        bytes: u64,
        /// Download delay charged.
        delay: f64,
    },
    /// Mirror of a recorder sample ([`crate::metrics::Sample`]), so a
    /// replay can be diffed against the trace alone.
    Sample {
        /// Iteration index of the sample.
        iteration: u64,
        /// Wall-clock time of the sample.
        time: f64,
        /// k at the sample.
        k: u32,
        /// Error metric at the sample.
        error: f64,
        /// Cumulative uplink bytes.
        bytes: u64,
        /// Cumulative upload time.
        comm_time: f64,
        /// Cumulative downlink bytes.
        bytes_down: u64,
        /// Cumulative download time.
        down_time: f64,
    },
}

impl Event {
    /// Wire kind tag.
    pub(super) fn kind(&self) -> u8 {
        match self {
            Event::Broadcast { .. } => KIND_BROADCAST,
            Event::Compute { .. } => KIND_COMPUTE,
            Event::Transmit { .. } => KIND_TRANSMIT,
            Event::IngressServe { .. } => KIND_INGRESS,
            Event::Apply { .. } => KIND_APPLY,
            Event::KChange { .. } => KIND_KCHANGE,
            Event::Push { .. } => KIND_PUSH,
            Event::Sample { .. } => KIND_SAMPLE,
        }
    }

    /// Append the payload bytes (fixed length per kind).
    pub(super) fn encode_payload(&self, out: &mut Vec<u8>) {
        match *self {
            Event::Broadcast { step, time, bytes } => {
                out.extend_from_slice(&step.to_le_bytes());
                out.extend_from_slice(&time.to_bits().to_le_bytes());
                out.extend_from_slice(&bytes.to_le_bytes());
            }
            Event::Compute {
                iteration,
                worker,
                raw,
                compute,
                upload,
                download,
            } => {
                out.extend_from_slice(&iteration.to_le_bytes());
                out.extend_from_slice(&worker.to_le_bytes());
                out.extend_from_slice(&raw.to_bits().to_le_bytes());
                out.extend_from_slice(&compute.to_bits().to_le_bytes());
                out.extend_from_slice(&upload.to_bits().to_le_bytes());
                out.extend_from_slice(&download.to_bits().to_le_bytes());
            }
            Event::Transmit { step, worker, bytes } => {
                out.extend_from_slice(&step.to_le_bytes());
                out.extend_from_slice(&worker.to_le_bytes());
                out.extend_from_slice(&bytes.to_le_bytes());
            }
            Event::IngressServe { worker, arrival, served } => {
                out.extend_from_slice(&worker.to_le_bytes());
                out.extend_from_slice(&arrival.to_bits().to_le_bytes());
                out.extend_from_slice(&served.to_bits().to_le_bytes());
            }
            Event::Apply { step, time, k, staleness } => {
                out.extend_from_slice(&step.to_le_bytes());
                out.extend_from_slice(&time.to_bits().to_le_bytes());
                out.extend_from_slice(&k.to_le_bytes());
                out.extend_from_slice(&staleness.to_le_bytes());
            }
            Event::KChange { step, time, k } => {
                out.extend_from_slice(&step.to_le_bytes());
                out.extend_from_slice(&time.to_bits().to_le_bytes());
                out.extend_from_slice(&k.to_le_bytes());
            }
            Event::Push { step, worker, bytes, delay } => {
                out.extend_from_slice(&step.to_le_bytes());
                out.extend_from_slice(&worker.to_le_bytes());
                out.extend_from_slice(&bytes.to_le_bytes());
                out.extend_from_slice(&delay.to_bits().to_le_bytes());
            }
            Event::Sample {
                iteration,
                time,
                k,
                error,
                bytes,
                comm_time,
                bytes_down,
                down_time,
            } => {
                out.extend_from_slice(&iteration.to_le_bytes());
                out.extend_from_slice(&time.to_bits().to_le_bytes());
                out.extend_from_slice(&k.to_le_bytes());
                out.extend_from_slice(&error.to_bits().to_le_bytes());
                out.extend_from_slice(&bytes.to_le_bytes());
                out.extend_from_slice(&comm_time.to_bits().to_le_bytes());
                out.extend_from_slice(&bytes_down.to_le_bytes());
                out.extend_from_slice(&down_time.to_bits().to_le_bytes());
            }
        }
    }

    /// Decode a payload for a known kind; `Ok(None)` for kinds this
    /// reader does not know (the caller already skipped the bytes).
    pub(super) fn decode(
        kind: u8,
        payload: &[u8],
    ) -> Result<Option<Event>, TraceError> {
        let mut c = Cursor::new(payload);
        let ev = match kind {
            KIND_BROADCAST => Event::Broadcast {
                step: c.u64("broadcast.step")?,
                time: c.f64("broadcast.time")?,
                bytes: c.u64("broadcast.bytes")?,
            },
            KIND_COMPUTE => Event::Compute {
                iteration: c.u64("compute.iteration")?,
                worker: c.u32("compute.worker")?,
                raw: c.f64("compute.raw")?,
                compute: c.f64("compute.compute")?,
                upload: c.f64("compute.upload")?,
                download: c.f64("compute.download")?,
            },
            KIND_TRANSMIT => Event::Transmit {
                step: c.u64("transmit.step")?,
                worker: c.u32("transmit.worker")?,
                bytes: c.u64("transmit.bytes")?,
            },
            KIND_INGRESS => Event::IngressServe {
                worker: c.u32("ingress.worker")?,
                arrival: c.f64("ingress.arrival")?,
                served: c.f64("ingress.served")?,
            },
            KIND_APPLY => Event::Apply {
                step: c.u64("apply.step")?,
                time: c.f64("apply.time")?,
                k: c.u32("apply.k")?,
                staleness: c.u64("apply.staleness")?,
            },
            KIND_KCHANGE => Event::KChange {
                step: c.u64("kchange.step")?,
                time: c.f64("kchange.time")?,
                k: c.u32("kchange.k")?,
            },
            KIND_PUSH => Event::Push {
                step: c.u64("push.step")?,
                worker: c.u32("push.worker")?,
                bytes: c.u64("push.bytes")?,
                delay: c.f64("push.delay")?,
            },
            KIND_SAMPLE => Event::Sample {
                iteration: c.u64("sample.iteration")?,
                time: c.f64("sample.time")?,
                k: c.u32("sample.k")?,
                error: c.f64("sample.error")?,
                bytes: c.u64("sample.bytes")?,
                comm_time: c.f64("sample.comm_time")?,
                bytes_down: c.u64("sample.bytes_down")?,
                down_time: c.f64("sample.down_time")?,
            },
            _ => return Ok(None),
        };
        if !c.is_eof() {
            return Err(TraceError::Format(format!(
                "event kind {kind} payload longer than its fixed layout \
                 ({} bytes)",
                payload.len()
            )));
        }
        Ok(Some(ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_events() -> Vec<Event> {
        vec![
            Event::Broadcast { step: 3, time: 1.5, bytes: 640 },
            Event::Compute {
                iteration: 3,
                worker: 2,
                raw: 0.25,
                compute: 0.5,
                upload: 0.125,
                download: 0.0625,
            },
            Event::Transmit { step: 3, worker: 2, bytes: 96 },
            Event::IngressServe { worker: 1, arrival: 2.0, served: 2.5 },
            Event::Apply { step: 3, time: 2.5, k: 4, staleness: 2 },
            Event::KChange { step: 3, time: 2.5, k: 5 },
            Event::Push { step: 4, worker: 1, bytes: 640, delay: 0.5 },
            Event::Sample {
                iteration: 4,
                time: 2.5,
                k: 5,
                error: 1e-3,
                bytes: 736,
                comm_time: 0.1875,
                bytes_down: 1280,
                down_time: 0.5,
            },
        ]
    }

    #[test]
    fn every_kind_round_trips() {
        for ev in all_events() {
            let mut payload = Vec::new();
            ev.encode_payload(&mut payload);
            let back = Event::decode(ev.kind(), &payload).unwrap().unwrap();
            assert_eq!(back, ev);
        }
    }

    #[test]
    fn nan_and_infinite_times_survive_bitwise() {
        let ev = Event::Sample {
            iteration: 0,
            time: f64::NAN,
            k: 1,
            error: f64::INFINITY,
            bytes: 0,
            comm_time: 0.0,
            bytes_down: 0,
            down_time: -0.0,
        };
        let mut payload = Vec::new();
        ev.encode_payload(&mut payload);
        match Event::decode(ev.kind(), &payload).unwrap().unwrap() {
            Event::Sample { time, error, down_time, .. } => {
                assert_eq!(time.to_bits(), f64::NAN.to_bits());
                assert_eq!(error, f64::INFINITY);
                assert_eq!(down_time.to_bits(), (-0.0f64).to_bits());
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn unknown_kind_decodes_to_none() {
        assert_eq!(Event::decode(99, &[1, 2, 3]).unwrap(), None);
    }

    #[test]
    fn oversized_payload_is_rejected() {
        let ev = Event::KChange { step: 0, time: 0.0, k: 1 };
        let mut payload = Vec::new();
        ev.encode_payload(&mut payload);
        payload.push(0xFF);
        assert!(Event::decode(ev.kind(), &payload).is_err());
    }
}
