//! Wire-level constants and primitives of the trace format.
//!
//! Layout of a trace file (all integers little-endian):
//!
//! ```text
//! magic      8 bytes   b"ADSGTRC\0"
//! major      u16       FORMAT_MAJOR
//! minor      u16       FORMAT_MINOR
//! discipline u8        Discipline tag
//! n_workers  u32
//! label_len  u16
//! label      label_len bytes of UTF-8
//! frames     until EOF:
//!   kind        u8     event kind (see event.rs)
//!   payload_len u8     fixed per kind within a major version
//!   payload     payload_len bytes
//! ```
//!
//! The per-frame `payload_len` is what makes minor versions
//! forward-skippable: a reader that does not know a kind still knows
//! how many bytes to jump. See the module docs of [`crate::trace`] for
//! the full version/compatibility policy.

use super::reader::TraceError;

/// File magic: identifies an adasgd event trace.
pub const MAGIC: [u8; 8] = *b"ADSGTRC\0";

/// Current major format version. Bumped when existing frames change
/// meaning or layout; readers must reject majors they don't support.
pub const FORMAT_MAJOR: u16 = 1;

/// Current minor format version. Bumped when event kinds are added;
/// readers skip unknown kinds via the frame's payload length.
pub const FORMAT_MINOR: u16 = 0;

/// Gather discipline that produced a trace (header field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// Synchronous fastest-k rounds (`master::run_fastest_k_comm`).
    Sync,
    /// Fully asynchronous staleness-aware updates (`async_sgd`).
    Async,
    /// Gradient-coded rounds (`coding::run_coded_comm`).
    Coded,
    /// Threaded cluster, round-based (`exec::ThreadedCluster`).
    Threaded,
    /// Threaded cluster, fully asynchronous.
    ThreadedAsync,
}

impl Discipline {
    /// Wire tag of the discipline.
    pub fn tag(self) -> u8 {
        match self {
            Discipline::Sync => 0,
            Discipline::Async => 1,
            Discipline::Coded => 2,
            Discipline::Threaded => 3,
            Discipline::ThreadedAsync => 4,
        }
    }

    /// Decode a wire tag.
    pub fn from_tag(tag: u8) -> Option<Self> {
        Some(match tag {
            0 => Discipline::Sync,
            1 => Discipline::Async,
            2 => Discipline::Coded,
            3 => Discipline::Threaded,
            4 => Discipline::ThreadedAsync,
            _ => return None,
        })
    }

    /// True when updates are applied per-round (all workers sampled
    /// every iteration) rather than per-completion. Round traces carry
    /// complete per-iteration delay rows, which is what
    /// `TraceDelays::from_event_trace` mines.
    pub fn is_round_based(self) -> bool {
        matches!(
            self,
            Discipline::Sync | Discipline::Coded | Discipline::Threaded
        )
    }
}

impl std::fmt::Display for Discipline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            Discipline::Sync => "sync",
            Discipline::Async => "async",
            Discipline::Coded => "coded",
            Discipline::Threaded => "threaded",
            Discipline::ThreadedAsync => "threaded-async",
        };
        f.write_str(name)
    }
}

/// Little-endian byte cursor over a trace buffer; every read is
/// bounds-checked and reports *what* was truncated.
pub(super) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub(super) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub(super) fn is_eof(&self) -> bool {
        self.pos >= self.buf.len()
    }

    pub(super) fn take(
        &mut self,
        n: usize,
        what: &str,
    ) -> Result<&'a [u8], TraceError> {
        if self.pos + n > self.buf.len() {
            return Err(TraceError::Format(format!(
                "truncated trace: expected {n} byte(s) of {what} at offset \
                 {}, file has {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(super) fn u8(&mut self, what: &str) -> Result<u8, TraceError> {
        Ok(self.take(1, what)?[0])
    }

    pub(super) fn u16(&mut self, what: &str) -> Result<u16, TraceError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub(super) fn u32(&mut self, what: &str) -> Result<u32, TraceError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(super) fn u64(&mut self, what: &str) -> Result<u64, TraceError> {
        let b = self.take(8, what)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    pub(super) fn f64(&mut self, what: &str) -> Result<f64, TraceError> {
        Ok(f64::from_bits(self.u64(what)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discipline_tags_round_trip() {
        for d in [
            Discipline::Sync,
            Discipline::Async,
            Discipline::Coded,
            Discipline::Threaded,
            Discipline::ThreadedAsync,
        ] {
            assert_eq!(Discipline::from_tag(d.tag()), Some(d));
        }
        assert_eq!(Discipline::from_tag(250), None);
        assert!(Discipline::Sync.is_round_based());
        assert!(!Discipline::Async.is_round_based());
    }

    #[test]
    fn cursor_reads_le_and_reports_truncation() {
        let buf = [0x01, 0x02, 0x03, 0x04];
        let mut c = Cursor::new(&buf);
        assert_eq!(c.u16("x").unwrap(), 0x0201);
        let err = c.u32("tail").unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        assert!(err.contains("tail"), "{err}");
    }
}
