//! Binary event trace: record, replay, and post-hoc analysis.
//!
//! Every question the paper's error–runtime trade-off raises — where
//! wall-clock time goes inside a round, how stale applied gradients are,
//! how long uploads queue at the master's ingress — used to require
//! re-running sweeps with fatter CSV columns. This module records the
//! answer once: the [`EngineCore`](crate::engine::EngineCore) emits a
//! compact binary [`Event`] stream (model broadcasts, per-worker compute
//! samples, uplink transmits, ingress service, gradient applies,
//! adaptive k-changes, recorder samples) under **every** gather
//! discipline — sync fastest-k, async staleness, coded, and the threaded
//! cluster — and the stream is a standalone artifact:
//!
//! * **Record** — `EngineCore::enable_trace` turns the stream on; the
//!   finished [`Trace`] rides out on
//!   [`EngineRun::trace`](crate::engine::EngineRun). Off by default and
//!   observationally free: no RNG draw, clock update, or recorder push
//!   moves when tracing is enabled, so traced and untraced runs are
//!   bit-identical (test-asserted).
//! * **Replay** — [`ReplayDelays`] turns a trace back into a
//!   [`DelayModel`](crate::straggler::DelayModel): re-running the same
//!   config against it reproduces the model trajectory, virtual clock,
//!   and recorder samples *bitwise*, because every live delay draw is
//!   keyed by `(iteration, worker)` and the trace stores the raw sample
//!   before pricing. [`TraceDelays::from_event_trace`]
//!   (crate::straggler::TraceDelays::from_event_trace) mines the same
//!   samples into a cyclic straggler scenario for new experiments.
//! * **Analyze** — [`TraceAnalysis`] computes per-worker utilization,
//!   ingress queueing delay, staleness histograms, and the per-round
//!   wait-time decomposition from a trace file alone (`trace analyze`
//!   in the CLI), without re-running anything.
//!
//! # On-disk format and version/compatibility policy
//!
//! A trace file is: an 8-byte magic (`b"ADSGTRC\0"`), a `u16` major and
//! `u16` minor format version (little-endian), a header (discipline
//! tag, worker count, run label), then length-prefixed event frames
//! until EOF. All integers are little-endian; all times are `f64` bit
//! patterns (`to_le_bytes`), so a round-trip through disk is exact.
//!
//! The compatibility contract, which readers MUST follow:
//!
//! * **Major version** (`FORMAT_MAJOR`): incremented when existing
//!   frames change meaning or layout. A reader encountering a major it
//!   does not support must reject the file with an actionable error
//!   (what it read, what it supports, what to do) — never panic,
//!   never guess.
//! * **Minor version** (`FORMAT_MINOR`): incremented when new event
//!   kinds are *added*. Every frame carries a one-byte payload length,
//!   so an old reader skips unknown kinds within its supported major
//!   and still parses the rest of the file.
//!
//! See `format.rs` for the wire layout and `reader.rs` for the
//! enforcement.

mod analyze;
mod display;
mod event;
mod format;
mod reader;
mod replay;
mod writer;

pub use analyze::{TraceAnalysis, WorkerUse};
pub use event::Event;
pub use format::{Discipline, FORMAT_MAJOR, FORMAT_MINOR, MAGIC};
pub use reader::TraceError;
pub use replay::ReplayDelays;

/// One recorded run: header fields plus the ordered event stream.
///
/// Construction sites are the engine (`EngineCore::enable_trace`) and
/// the reader ([`Trace::from_bytes`] / [`Trace::load`]); both produce
/// the same in-memory value, so everything downstream (replay, analyze,
/// display) is agnostic to whether the trace was just recorded or read
/// back from disk.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Which gather discipline produced the stream.
    pub discipline: Discipline,
    /// Worker count of the run (the comm channel's `n`).
    pub n_workers: u32,
    /// Run label (the engine's recorder label at enable time).
    pub label: String,
    /// Ordered event stream.
    pub events: Vec<Event>,
}

impl Trace {
    /// Empty trace with the given header.
    pub fn new(
        discipline: Discipline,
        n_workers: u32,
        label: impl Into<String>,
    ) -> Self {
        Self { discipline, n_workers, label: label.into(), events: Vec::new() }
    }

    /// Append one event.
    #[inline]
    pub fn push(&mut self, ev: Event) {
        self.events.push(ev);
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when no events were recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Map a run label to a filesystem-safe trace file stem: ASCII
/// alphanumerics, `.`, `-`, and `_` pass through, everything else
/// (sweep labels contain `/`) becomes `_`.
pub fn sanitize_label(label: &str) -> String {
    label
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '.' | '-' | '_') {
                c
            } else {
                '_'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_keeps_safe_chars_and_replaces_the_rest() {
        assert_eq!(sanitize_label("train_seed-1.0"), "train_seed-1.0");
        assert_eq!(sanitize_label("topk10/k=40"), "topk10_k_40");
        assert_eq!(sanitize_label("a b\tc"), "a_b_c");
    }

    #[test]
    fn trace_push_and_len() {
        let mut t = Trace::new(Discipline::Sync, 4, "x");
        assert!(t.is_empty());
        t.push(Event::KChange { step: 0, time: 1.0, k: 2 });
        assert_eq!(t.len(), 1);
        assert_eq!(t.n_workers, 4);
    }
}
