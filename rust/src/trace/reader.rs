//! Deserialisation and version enforcement for trace files.
//!
//! The reader implements the compatibility policy documented on
//! [`crate::trace`]: a file whose major version is newer than this
//! build is rejected with an actionable error (never a panic); frames
//! whose kind this build does not know — a newer *minor* version —
//! are skipped via their payload-length prefix.

use super::event::Event;
use super::format::{Cursor, Discipline, FORMAT_MAJOR, MAGIC};
use super::Trace;
use std::path::Path;

/// Errors reading a trace file.
#[derive(Debug)]
pub enum TraceError {
    /// Filesystem failure.
    Io(std::io::Error),
    /// The bytes are not a trace this build can parse.
    Format(String),
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Io(e) => write!(f, "trace io error: {e}"),
            TraceError::Format(msg) => write!(f, "trace format error: {msg}"),
        }
    }
}

impl std::error::Error for TraceError {}

impl Trace {
    /// Parse a trace from its binary form.
    pub fn from_bytes(bytes: &[u8]) -> Result<Trace, TraceError> {
        let mut c = Cursor::new(bytes);
        let magic = c.take(MAGIC.len(), "file magic")?;
        if magic != MAGIC {
            return Err(TraceError::Format(
                "not an adasgd event trace (bad magic); expected a file \
                 written by Trace::save / the --trace flag"
                    .into(),
            ));
        }
        let major = c.u16("format major version")?;
        let minor = c.u16("format minor version")?;
        if major > FORMAT_MAJOR {
            return Err(TraceError::Format(format!(
                "trace format v{major}.{minor} is newer than the v\
                 {FORMAT_MAJOR} this build supports; re-record the trace \
                 with this build, or upgrade the reader"
            )));
        }
        let tag = c.u8("discipline tag")?;
        let discipline = Discipline::from_tag(tag).ok_or_else(|| {
            TraceError::Format(format!(
                "unknown discipline tag {tag} (trace v{major}.{minor})"
            ))
        })?;
        let n_workers = c.u32("worker count")?;
        let label_len = c.u16("label length")? as usize;
        let label = std::str::from_utf8(c.take(label_len, "label")?)
            .map_err(|e| TraceError::Format(format!("label not UTF-8: {e}")))?
            .to_string();
        let mut events = Vec::new();
        while !c.is_eof() {
            let kind = c.u8("frame kind")?;
            let payload_len = c.u8("frame payload length")? as usize;
            let payload = c.take(payload_len, "frame payload")?;
            // Unknown kinds within a supported major come from newer
            // minor versions: skip them (the length prefix exists for
            // exactly this) and keep parsing.
            if let Some(ev) = Event::decode(kind, payload)? {
                events.push(ev);
            }
        }
        Ok(Trace { discipline, n_workers, label, events })
    }

    /// Read a trace file from disk.
    pub fn load(path: &Path) -> Result<Trace, TraceError> {
        let bytes = std::fs::read(path).map_err(TraceError::Io)?;
        Self::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let mut t = Trace::new(Discipline::Async, 3, "round/trip");
        t.push(Event::Broadcast { step: 0, time: 0.0, bytes: 24 });
        t.push(Event::Compute {
            iteration: 0,
            worker: 2,
            raw: 0.75,
            compute: 0.75,
            upload: 0.0,
            download: 0.25,
        });
        t.push(Event::Apply { step: 1, time: 1.0, k: 1, staleness: 3 });
        t
    }

    #[test]
    fn bytes_round_trip_exactly() {
        let t = sample_trace();
        let back = Trace::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn file_round_trip() {
        let t = sample_trace();
        let dir = std::env::temp_dir().join("adasgd_trace_reader_unit");
        let path = dir.join("nested/dir/a.trace");
        t.save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap(), t);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_is_an_error_not_a_panic() {
        let err = Trace::from_bytes(b"CSV,not,a,trace\n").unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn newer_major_is_rejected_with_guidance() {
        let mut bytes = sample_trace().to_bytes();
        bytes[8..10].copy_from_slice(&2u16.to_le_bytes());
        let err = Trace::from_bytes(&bytes).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("v2.0"), "{msg}");
        assert!(msg.contains("re-record"), "actionable: {msg}");
    }

    #[test]
    fn newer_minor_with_unknown_kind_is_skipped() {
        let t = sample_trace();
        let mut bytes = t.to_bytes();
        bytes[10..12].copy_from_slice(&9u16.to_le_bytes()); // minor = 9
        // Append an unknown frame kind with a 4-byte payload, then a
        // known frame; both must survive a v1 reader.
        bytes.extend_from_slice(&[200, 4, 1, 2, 3, 4]);
        let mut tail = Vec::new();
        let ev = Event::KChange { step: 9, time: 9.0, k: 9 };
        ev.encode_payload(&mut tail);
        bytes.push(6);
        bytes.push(tail.len() as u8);
        bytes.extend_from_slice(&tail);
        let back = Trace::from_bytes(&bytes).unwrap();
        assert_eq!(back.events.len(), t.events.len() + 1);
        assert_eq!(*back.events.last().unwrap(), ev);
    }

    #[test]
    fn truncated_frame_is_reported() {
        let mut bytes = sample_trace().to_bytes();
        bytes.truncate(bytes.len() - 3);
        let err = Trace::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }
}
