//! Replay a recorded trace in place of live delay sampling.
//!
//! The engine draws every worker delay through
//! `DelayModel::sample(iteration, worker, rng)` on a *dedicated* RNG
//! stream, and the trace records each draw's raw (pre-pricing) value
//! keyed by exactly that `(iteration, worker)` pair. [`ReplayDelays`]
//! is therefore a drop-in [`DelayModel`] that returns the recorded
//! value for each key and ignores the RNG — the broadcast and comm
//! streams never see a different draw order, so re-running the same
//! config against a `ReplayDelays` reproduces the original model
//! trajectory, virtual clock, and recorder samples bit for bit
//! (asserted in `rust/tests/test_trace_replay.rs` across all four
//! gather disciplines).

use super::{Event, Trace};
use crate::straggler::{DelayModel, RngDyn};
use std::collections::BTreeMap;

/// Exact delay replay of one recorded run.
#[derive(Debug, Clone)]
pub struct ReplayDelays {
    map: BTreeMap<(u64, u32), f64>,
    label: String,
}

impl ReplayDelays {
    /// Index a trace's `Compute` events by `(iteration, worker)`.
    ///
    /// Errors when the trace has no compute samples, or when one key
    /// was recorded twice with different values (a corrupt or
    /// concatenated trace — replay would be ambiguous).
    pub fn from_trace(trace: &Trace) -> Result<Self, String> {
        let mut map = BTreeMap::new();
        for ev in &trace.events {
            if let Event::Compute { iteration, worker, raw, .. } = *ev {
                if let Some(prev) = map.insert((iteration, worker), raw) {
                    if prev.to_bits() != raw.to_bits() {
                        return Err(format!(
                            "trace records two different delays for \
                             (iteration {iteration}, worker {worker}); \
                             cannot replay an ambiguous trace"
                        ));
                    }
                }
            }
        }
        if map.is_empty() {
            return Err(
                "trace has no compute events; nothing to replay".into()
            );
        }
        Ok(Self { map, label: trace.label.clone() })
    }

    /// Number of recorded `(iteration, worker)` delay keys.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Never true — construction rejects empty traces.
    pub fn is_empty(&self) -> bool {
        false
    }
}

impl DelayModel for ReplayDelays {
    /// Panics when the run requests a key the trace never recorded:
    /// that means the replay config does not match the recorded run
    /// (different n, policy, iteration budget, …), and silently
    /// inventing a delay would defeat the bitwise-equivalence contract.
    fn sample(
        &self,
        iteration: u64,
        worker: usize,
        _rng: &mut dyn RngDyn,
    ) -> f64 {
        *self.map.get(&(iteration, worker as u32)).unwrap_or_else(|| {
            panic!(
                "replay of {:?}: trace has no delay for (iteration \
                 {iteration}, worker {worker}) — the replay config must \
                 match the recorded run",
                self.label
            )
        })
    }

    fn name(&self) -> String {
        format!("replay({})", self.label)
    }

    fn is_iid(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::super::Discipline;
    use super::*;
    use crate::rng::Pcg64;

    fn compute(it: u64, w: u32, raw: f64) -> Event {
        Event::Compute {
            iteration: it,
            worker: w,
            raw,
            compute: raw,
            upload: 0.0,
            download: 0.0,
        }
    }

    #[test]
    fn replays_recorded_draws_exactly() {
        let mut t = Trace::new(Discipline::Sync, 2, "r");
        t.push(compute(0, 0, 0.125));
        t.push(compute(0, 1, 7.5));
        t.push(compute(1, 0, 2.25));
        let r = ReplayDelays::from_trace(&t).unwrap();
        let mut rng = Pcg64::seed(0);
        assert_eq!(r.sample(0, 1, &mut rng), 7.5);
        assert_eq!(r.sample(1, 0, &mut rng), 2.25);
        assert_eq!(r.len(), 3);
        assert!(!r.is_iid());
        assert!(r.name().contains('r'));
    }

    #[test]
    fn empty_and_ambiguous_traces_are_rejected() {
        let t = Trace::new(Discipline::Sync, 2, "e");
        assert!(ReplayDelays::from_trace(&t)
            .unwrap_err()
            .contains("no compute events"));
        let mut t2 = Trace::new(Discipline::Sync, 2, "dup");
        t2.push(compute(0, 0, 1.0));
        t2.push(compute(0, 0, 2.0));
        assert!(ReplayDelays::from_trace(&t2)
            .unwrap_err()
            .contains("ambiguous"));
    }

    #[test]
    #[should_panic(expected = "must match the recorded run")]
    fn missing_key_panics_with_guidance() {
        let mut t = Trace::new(Discipline::Sync, 1, "m");
        t.push(compute(0, 0, 1.0));
        let r = ReplayDelays::from_trace(&t).unwrap();
        let mut rng = Pcg64::seed(0);
        r.sample(5, 0, &mut rng);
    }
}
