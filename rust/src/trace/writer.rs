//! Binary serialisation of a [`Trace`] (see `format.rs` for layout).

use super::format::{FORMAT_MAJOR, FORMAT_MINOR, MAGIC};
use super::reader::TraceError;
use super::Trace;
use std::path::Path;

impl Trace {
    /// Serialise to the versioned binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        // Header + a conservative 32 bytes per frame avoids most regrows.
        let mut out = Vec::with_capacity(32 + self.label.len() + 32 * self.events.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_MAJOR.to_le_bytes());
        out.extend_from_slice(&FORMAT_MINOR.to_le_bytes());
        out.push(self.discipline.tag());
        out.extend_from_slice(&self.n_workers.to_le_bytes());
        let label = self.label.as_bytes();
        let label_len =
            u16::try_from(label.len()).unwrap_or(u16::MAX) as usize;
        out.extend_from_slice(&(label_len as u16).to_le_bytes());
        out.extend_from_slice(&label[..label_len]);
        let mut payload = Vec::with_capacity(64);
        for ev in &self.events {
            payload.clear();
            ev.encode_payload(&mut payload);
            debug_assert!(
                payload.len() <= u8::MAX as usize,
                "event payloads are fixed-size and < 256 bytes"
            );
            out.push(ev.kind());
            out.push(payload.len() as u8);
            out.extend_from_slice(&payload);
        }
        out
    }

    /// Write the trace to `path`, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<(), TraceError> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(TraceError::Io)?;
            }
        }
        std::fs::write(path, self.to_bytes()).map_err(TraceError::Io)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Discipline, Event};
    use super::*;

    #[test]
    fn header_bytes_are_the_documented_layout() {
        let t = Trace::new(Discipline::Coded, 7, "ab");
        let bytes = t.to_bytes();
        assert_eq!(&bytes[..8], b"ADSGTRC\0");
        assert_eq!(u16::from_le_bytes([bytes[8], bytes[9]]), FORMAT_MAJOR);
        assert_eq!(u16::from_le_bytes([bytes[10], bytes[11]]), FORMAT_MINOR);
        assert_eq!(bytes[12], Discipline::Coded.tag());
        assert_eq!(
            u32::from_le_bytes([bytes[13], bytes[14], bytes[15], bytes[16]]),
            7
        );
        assert_eq!(u16::from_le_bytes([bytes[17], bytes[18]]), 2);
        assert_eq!(&bytes[19..21], b"ab");
        assert_eq!(bytes.len(), 21, "no frames after an empty event list");
    }

    #[test]
    fn frames_are_length_prefixed() {
        let mut t = Trace::new(Discipline::Sync, 1, "");
        t.push(Event::KChange { step: 1, time: 2.0, k: 3 });
        let bytes = t.to_bytes();
        let frame = &bytes[19..];
        assert_eq!(frame[0], 6, "KChange kind tag");
        assert_eq!(frame[1] as usize, frame.len() - 2, "payload length");
    }
}
