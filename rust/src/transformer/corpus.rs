//! Synthetic token corpus with learnable structure.
//!
//! Sequences are built from a per-seed random vocabulary of `n_words`
//! fixed "words" (short token n-grams) sampled by a biased (Zipf-ish)
//! distribution. A bigram LM can compress this well below the uniform
//! `log V` entropy, so the e2e loss curve has real signal — unlike pure
//! iid-random tokens, which are unlearnable by construction.

use crate::rng::{Pcg64, Rng};

/// Deterministic synthetic corpus generator.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    vocab: usize,
    words: Vec<Vec<i32>>,
    seed: u64,
}

impl SyntheticCorpus {
    /// Build a corpus with `n_words` latent words over `vocab` tokens.
    pub fn new(vocab: usize, n_words: usize, word_len: usize, seed: u64) -> Self {
        assert!(vocab >= 4, "need a few tokens");
        assert!(n_words >= 1 && word_len >= 1);
        let mut rng = Pcg64::seed_stream(seed, 0xC0ff);
        let words = (0..n_words)
            .map(|_| {
                (0..word_len)
                    .map(|_| rng.gen_range_u64(0, vocab as u64 - 1) as i32)
                    .collect()
            })
            .collect();
        Self { vocab, words, seed }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// One `(batch, seq_len + 1)` token batch, flattened row-major.
    /// Deterministic in `(iteration, worker)` — workers regenerate their
    /// microbatches instead of storing the corpus.
    pub fn batch(
        &self,
        batch: usize,
        seq_plus1: usize,
        iteration: u64,
        worker: usize,
    ) -> Vec<i32> {
        let mut rng = Pcg64::seed_stream(
            self.seed ^ iteration.wrapping_mul(0x9E3779B97F4A7C15),
            worker as u64,
        );
        let mut out = Vec::with_capacity(batch * seq_plus1);
        for _ in 0..batch {
            let mut row = Vec::with_capacity(seq_plus1 + 8);
            while row.len() < seq_plus1 {
                // Zipf-ish word pick: square the uniform to bias low ids.
                let u = rng.next_f64();
                let idx = ((u * u) * self.words.len() as f64) as usize;
                row.extend_from_slice(&self.words[idx.min(self.words.len() - 1)]);
            }
            row.truncate(seq_plus1);
            out.extend_from_slice(&row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_and_deterministic() {
        let c = SyntheticCorpus::new(256, 32, 4, 7);
        let a = c.batch(8, 65, 3, 1);
        let b = c.batch(8, 65, 3, 1);
        assert_eq!(a.len(), 8 * 65);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..256).contains(&t)));
        // Different iteration/worker → different batch.
        assert_ne!(a, c.batch(8, 65, 4, 1));
        assert_ne!(a, c.batch(8, 65, 3, 2));
    }

    #[test]
    fn corpus_has_structure() {
        // Token bigrams must be far from uniform: count distinct bigrams
        // in a large sample — with 32 words of length 4 over 256 tokens,
        // the within-word transitions dominate and distinct bigrams are
        // far fewer than the ~65k possible.
        let c = SyntheticCorpus::new(256, 32, 4, 9);
        let toks = c.batch(64, 257, 0, 0);
        let mut seen = std::collections::BTreeSet::new();
        for row in toks.chunks(257) {
            for w in row.windows(2) {
                seen.insert((w[0], w[1]));
            }
        }
        assert!(
            seen.len() < 6000,
            "bigram support too large to be learnable: {}",
            seen.len()
        );
    }
}
