//! End-to-end transformer-LM training through the fastest-k coordinator.
//!
//! Proves the full stack composes: the Pallas matmul kernel sits inside
//! the JAX train-step graph, AOT-lowered to `transformer_grad_{tag}` /
//! `transformer_step_{tag}` HLO artifacts, which this module executes via
//! PJRT from the same master loop that trains linear regression. The model
//! is an opaque flat `f32` parameter vector to the coordinator — exactly
//! how the paper's scheme is workload-agnostic.

mod corpus;
#[cfg(feature = "pjrt")]
mod trainer;

pub use corpus::SyntheticCorpus;
#[cfg(feature = "pjrt")]
pub use trainer::{TransformerBackend, TransformerSession};
