//! PJRT-backed transformer training session.

use super::SyntheticCorpus;
use crate::grad::GradBackend;
use crate::runtime::{Arg, Executable, Runtime, RuntimeError};
use std::sync::Arc;

/// Data-parallel transformer gradient backend: worker `i`'s "shard" is a
/// rotating stream of microbatches; its partial gradient is the LM loss
/// gradient of the current microbatch, computed by the
/// `transformer_grad_{tag}` artifact (Pallas matmul inside).
pub struct TransformerBackend {
    grad_exe: Executable,
    corpus: SyntheticCorpus,
    n_workers: usize,
    p: usize,
    batch: usize,
    seq_plus1: usize,
    iteration: u64,
    /// Loss of the most recent partial-gradient execution (diagnostics).
    pub last_loss: f32,
}

impl TransformerBackend {
    /// Load the grad artifact for `tag` and wrap a corpus.
    pub fn new(
        runtime: &Arc<Runtime>,
        tag: &str,
        n_workers: usize,
        corpus_seed: u64,
    ) -> Result<Self, RuntimeError> {
        let grad_exe = runtime.load(&format!("transformer_grad_{tag}"))?;
        let info = grad_exe.info();
        let p = info.meta_usize("params").ok_or_else(|| {
            RuntimeError::Manifest("transformer_grad missing 'params' meta".into())
        })?;
        let batch = info.meta_usize("batch").unwrap_or(8);
        let seq_len = info.meta_usize("seq_len").unwrap_or(64);
        let vocab = info.meta_usize("vocab").unwrap_or(256);
        let corpus = SyntheticCorpus::new(vocab, 32, 4, corpus_seed);
        Ok(Self {
            grad_exe,
            corpus,
            n_workers,
            p,
            batch,
            seq_plus1: seq_len + 1,
            iteration: 0,
            last_loss: f32::NAN,
        })
    }

    /// Parameter count P.
    pub fn params(&self) -> usize {
        self.p
    }

    /// Gradient + loss on an explicit token batch.
    pub fn grad_on(
        &self,
        params: &[f32],
        tokens: &[i32],
        out: &mut [f32],
    ) -> Result<f32, RuntimeError> {
        let outputs =
            self.grad_exe.run(&[Arg::F32(params), Arg::I32(tokens)])?;
        let mut loss = [0.0f32];
        crate::runtime::copy_f32(&outputs[0], out, "transformer_grad")?;
        crate::runtime::copy_f32(&outputs[1], &mut loss, "transformer_grad")?;
        Ok(loss[0])
    }

    /// A held-out evaluation batch (fixed across the run).
    pub fn eval_tokens(&self) -> Vec<i32> {
        self.corpus.batch(self.batch, self.seq_plus1, u64::MAX / 2, 0)
    }

    /// Evaluate the LM loss at `params` on the held-out batch.
    pub fn eval_loss(&self, params: &[f32]) -> Result<f32, RuntimeError> {
        let tokens = self.eval_tokens();
        let mut scratch = vec![0.0f32; self.p];
        self.grad_on(params, &tokens, &mut scratch)
    }
}

impl GradBackend for TransformerBackend {
    fn partial_grad(&mut self, shard: usize, w: &[f32], out: &mut [f32]) {
        let tokens =
            self.corpus
                .batch(self.batch, self.seq_plus1, self.iteration, shard);
        self.last_loss = self
            .grad_on(w, &tokens, out)
            .expect("transformer grad execution failed");
    }

    fn on_iteration(&mut self, j: u64) {
        self.iteration = j;
    }

    fn dim(&self) -> usize {
        self.p
    }

    fn n_shards(&self) -> usize {
        self.n_workers
    }

    fn name(&self) -> &'static str {
        "transformer-xla"
    }
}

/// Single-process training session using the fused step artifact
/// (`transformer_step_{tag}`) — the fastest path for the e2e example's
/// baseline and for profiling L2.
pub struct TransformerSession {
    step_exe: Executable,
    init_exe: Executable,
    corpus: SyntheticCorpus,
    p: usize,
    batch: usize,
    seq_plus1: usize,
}

impl TransformerSession {
    /// Load the step + init artifacts for `tag`.
    pub fn new(
        runtime: &Arc<Runtime>,
        tag: &str,
        corpus_seed: u64,
    ) -> Result<Self, RuntimeError> {
        let step_exe = runtime.load(&format!("transformer_step_{tag}"))?;
        let init_exe = runtime.load(&format!("transformer_init_{tag}"))?;
        let info = step_exe.info();
        let p = info.meta_usize("params").ok_or_else(|| {
            RuntimeError::Manifest("transformer_step missing 'params' meta".into())
        })?;
        let batch = info.meta_usize("batch").unwrap_or(8);
        let seq_len = info.meta_usize("seq_len").unwrap_or(64);
        let vocab = info.meta_usize("vocab").unwrap_or(256);
        Ok(Self {
            step_exe,
            init_exe,
            corpus: SyntheticCorpus::new(vocab, 32, 4, corpus_seed),
            p,
            batch,
            seq_plus1: seq_len + 1,
        })
    }

    /// Parameter count P.
    pub fn params(&self) -> usize {
        self.p
    }

    /// Deterministic parameter init via the `transformer_init` artifact
    /// (so Rust never reimplements the JAX init).
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>, RuntimeError> {
        let outputs = self.init_exe.run(&[Arg::I32(&[seed])])?;
        let mut params = vec![0.0f32; self.p];
        crate::runtime::copy_f32(&outputs[0], &mut params, "transformer_init")?;
        Ok(params)
    }

    /// One fused train step; returns the loss. `params` is updated in
    /// place (host-side copy of the donated-style update).
    pub fn step(
        &self,
        params: &mut [f32],
        eta: f32,
        iteration: u64,
    ) -> Result<f32, RuntimeError> {
        let tokens =
            self.corpus.batch(self.batch, self.seq_plus1, iteration, 0);
        let eta_arr = [eta];
        let outputs = self.step_exe.run(&[
            Arg::F32(params),
            Arg::I32(&tokens),
            Arg::F32(&eta_arr),
        ])?;
        let mut loss = [0.0f32];
        crate::runtime::copy_f32(&outputs[0], params, "transformer_step")?;
        crate::runtime::copy_f32(&outputs[1], &mut loss, "transformer_step")?;
        Ok(loss[0])
    }
}
