// D001 must fire twice: unwrap and expect forms.
fn sort_delays(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
}
