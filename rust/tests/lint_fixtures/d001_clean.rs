// total_cmp ordering and propagated Options are fine.
fn sort_delays(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.total_cmp(b));
}
fn compare(a: f64, b: f64) -> Option<std::cmp::Ordering> {
    a.partial_cmp(&b)
}
