// D002 must fire in a deterministic module: hash iteration order is
// process-seeded.
use std::collections::{HashMap, HashSet};
fn tally(xs: &[u64]) -> HashMap<u64, usize> {
    let mut m = HashMap::new();
    let mut seen = HashSet::new();
    for &x in xs {
        if seen.insert(x) {
            m.insert(x, 1);
        }
    }
    m
}
