// Ordered collections keep traversals deterministic.
use std::collections::{BTreeMap, BTreeSet};
fn tally(xs: &[u64]) -> BTreeMap<u64, usize> {
    let mut m = BTreeMap::new();
    let mut seen = BTreeSet::new();
    for &x in xs {
        if seen.insert(x) {
            m.insert(x, 1);
        }
    }
    m
}
