// Pragma-suppressed wall clock: still reported, not gate-failing.
use std::time::Instant;
fn stamp() -> f64 {
    // feeds a reported stat only. detlint: allow(D003)
    let t = Instant::now();
    t.elapsed().as_secs_f64()
}
