// D003 must fire twice: Instant::now and a SystemTime read.
use std::time::Instant;
fn stamp() -> f64 {
    let t = Instant::now();
    let _ = std::time::SystemTime::now();
    t.elapsed().as_secs_f64()
}
