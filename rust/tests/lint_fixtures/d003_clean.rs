// The virtual clock is a plain f64 accumulator; tests may also use
// wall clocks freely.
fn advance(clock: &mut f64, dt: f64) {
    *clock += dt;
}
#[cfg(test)]
mod tests {
    #[test]
    fn wall_clock_in_tests_is_fine() {
        let _ = std::time::Instant::now();
    }
}
