// D004 must fire: a hard-coded integer seed ignores --seed.
fn make_rng() -> Pcg64 {
    Pcg64::seed_stream(42, 7)
}
