// Streams derived from the run seed are fine, as are literal stream
// ids in the second argument.
fn make_rng(seed: u64) -> Pcg64 {
    Pcg64::seed_stream(seed, 0x0515)
}
