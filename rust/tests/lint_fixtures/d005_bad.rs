// D005 must fire on all four print macros in library code.
fn report(x: f64) {
    println!("x = {x}");
    eprintln!("warning");
    print!("partial");
    eprint!("partial err");
}
