// Library code returns data; tests may print.
fn report(x: f64) -> String {
    format!("x = {x}")
}
#[cfg(test)]
mod tests {
    #[test]
    fn printing_in_tests_is_fine() {
        println!("debug output");
    }
}
