// D006 must fire on both spellings of a live spawn outside exec.
fn fan_out(shards: Vec<Vec<f32>>) {
    let h = std::thread::spawn(move || shards.len());
    let _ = h.join();
    let h2 = thread::spawn(|| 0usize);
    let _ = h2.join();
}
