// Parallelism routed through exec is the sanctioned shape; tests may
// spawn scenario threads freely.
use crate::exec::Parallelism;
fn fan_out(par: Parallelism, y: &mut [f32]) {
    crate::exec::for_each_block_mut(par, y, |_, chunk| {
        for v in chunk {
            *v += 1.0;
        }
    });
}
#[cfg(test)]
mod tests {
    #[test]
    fn scenario_threads_are_fine() {
        let h = std::thread::spawn(|| 1 + 1);
        assert_eq!(h.join().unwrap(), 2);
    }
}
