// L001 must fire: these edges are outside the layering table for
// `engine` (sweep sits above the engine; cli is globally forbidden).
use crate::sweep::derive_seed;
use crate::cli::Args;
fn f() {}
