// Table-sanctioned engine imports, grouped form included; tests may
// reach across layers.
use crate::comm::CommStream;
use crate::{rng::Pcg64, straggler::DelayModel};
#[cfg(test)]
mod tests {
    use crate::sweep::derive_seed;
}
// The fastpath's order-statistics edge is table-sanctioned.
use crate::stats::OrderStatSampler;
// The heterogeneous fastpath rides the same sanctioned edges:
// engine → stats (class-merge sampler) and engine → comm (priced
// uplink constants + FIFO ingress chain).
use crate::stats::ClassOrderSampler;
use crate::comm::IngressModel;
