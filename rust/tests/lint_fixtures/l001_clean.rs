// Table-sanctioned engine imports, grouped form included; tests may
// reach across layers.
use crate::comm::CommStream;
use crate::{rng::Pcg64, straggler::DelayModel};
#[cfg(test)]
mod tests {
    use crate::sweep::derive_seed;
}
// The fastpath's order-statistics edge is table-sanctioned.
use crate::stats::OrderStatSampler;
