// Every construct here LOOKS like a violation but is comment/string
// content; the whole file must lint clean in any module.
/* block comment: Instant::now() and HashMap
   /* nested: partial_cmp(x).unwrap() */
   still the outer comment: println!("x") */
fn torture<'a>(tag: &'a str) -> String {
    let s = "Instant::now() // not a comment, HashMap inside string";
    let r = r#"raw: partial_cmp(b).unwrap() and "quoted" println!"#;
    let rr = r##"raw with hash: Pcg64::seed_stream(42, 7) "#"##;
    let c = '"';
    let nl = '\n';
    let lifetime_not_char: &'static str = "SystemTime";
    let cont = "split \
                across lines: eprintln!";
    format!("{tag}{s}{r}{rr}{c}{nl}{lifetime_not_char}{cont}")
}
