// S001 must fire twice: CSV_COLUMNS dropped columns vs the registered
// schema, and the writer still claims an old series version.
pub const CSV_COLUMNS: &str = "label,iteration,time,k,error";
fn write_header() -> String {
    String::from("# adasgd run series v3; columns")
}
