// S001 must fire three times: duplicate tag, reserved tag 0, and a
// kind missing from decode().
const KIND_BROADCAST: u8 = 1;
const KIND_COMPUTE: u8 = 1;
const KIND_RESERVED: u8 = 0;
const KIND_HALFWIRED: u8 = 5;
fn kind(which: usize) -> u8 {
    [KIND_BROADCAST, KIND_COMPUTE, KIND_RESERVED, KIND_HALFWIRED][which]
}
fn decode(k: u8) -> bool {
    k == KIND_BROADCAST || k == KIND_COMPUTE || k == KIND_RESERVED
}
