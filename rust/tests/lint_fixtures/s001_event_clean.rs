// Fully wired tags: unique, nonzero, present in kind() and decode().
const KIND_BROADCAST: u8 = 1;
const KIND_COMPUTE: u8 = 2;
fn kind(which: usize) -> u8 {
    [KIND_BROADCAST, KIND_COMPUTE][which]
}
fn decode(k: u8) -> bool {
    k == KIND_BROADCAST || k == KIND_COMPUTE
}
