//! Property-based tests over coordinator invariants, via the in-repo
//! `proptest_lite` harness (no proptest crate offline).

use adasgd::master::fastest_k_select;
use adasgd::policy::{AdaptivePflug, FixedK, IterationObs, KPolicy, PflugParams};
use adasgd::proptest_lite::{Gen, Pair, Runner, UsizeRange, VecF64};
use adasgd::rng::{Pcg64, Rng};
use adasgd::sim::EventQueue;
use adasgd::stats::OrderStats;
use adasgd::theory::{switching_times, BoundParams, ErrorBound};

fn runner() -> Runner {
    Runner { cases: 200, seed: 0xADA5, max_shrinks: 100 }
}

/// fastest_k_select must return exactly the k-th order statistic and the
/// set of the k smallest entries, for any delays and any valid k.
#[test]
fn prop_fastest_k_select_matches_sort() {
    let gen = Pair(
        VecF64 { min_len: 1, max_len: 64, lo: 0.001, hi: 100.0 },
        UsizeRange { lo: 0, hi: 1_000_000 },
    );
    runner().check("fastest_k_select", &gen, |(delays, kraw)| {
        let n = delays.len();
        let k = 1 + kraw % n;
        let mut idx = Vec::new();
        let (x_k, _) = fastest_k_select(delays, k, &mut idx);
        let mut sorted = delays.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if (x_k - sorted[k - 1]).abs() > 1e-12 {
            return Err(format!("x_k {} != sorted[k-1] {}", x_k, sorted[k - 1]));
        }
        let mut chosen: Vec<f64> = idx[..k].iter().map(|&i| delays[i]).collect();
        chosen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (c, s) in chosen.iter().zip(&sorted[..k]) {
            if (c - s).abs() > 1e-12 {
                return Err(format!("selected set mismatch: {chosen:?}"));
            }
        }
        // No duplicate worker indices.
        let mut ids: Vec<usize> = idx[..k].to_vec();
        ids.sort_unstable();
        ids.dedup();
        if ids.len() != k {
            return Err("duplicate worker in selection".into());
        }
        Ok(())
    });
}

/// AdaptivePflug: k is monotone non-decreasing, within [k0, k_max], moves
/// only in multiples of `step`, and switches are separated by > burnin.
#[test]
fn prop_adaptive_pflug_state_machine() {
    let gen = Pair(
        UsizeRange { lo: 2, hi: 64 },  // n
        UsizeRange { lo: 0, hi: u32::MAX as usize }, // sign-pattern seed
    );
    runner().check("pflug_invariants", &gen, |&(n, seed)| {
        let params = PflugParams {
            k0: 1 + seed % n.max(1),
            step: 1 + seed % 7,
            thresh: 1 + (seed % 9) as i64,
            burnin: (seed % 50) as u64,
            k_max: n,
        };
        let params = PflugParams { k0: params.k0.min(n), ..params };
        let mut p = AdaptivePflug::new(n, params);
        let mut rng = Pcg64::seed(seed as u64);
        let mut prev_k = p.initial_k();
        let mut last_switch: Option<u64> = None;
        for j in 0..2000u64 {
            let inner = if rng.next_f64() < 0.6 { -1.0 } else { 1.0 };
            let k = p.next_k(&IterationObs {
                iteration: j,
                time: j as f64,
                k_used: prev_k,
                grad_inner_prev: if j == 0 { None } else { Some(inner) },
                grad_norm_sq: 1.0,
            });
            if k < prev_k {
                return Err(format!("k decreased: {prev_k} -> {k} at j={j}"));
            }
            if k > params.k_max {
                return Err(format!("k={k} above k_max={}", params.k_max));
            }
            if k != prev_k {
                if (k - prev_k) != params.step {
                    return Err(format!(
                        "switch moved by {} not step={}",
                        k - prev_k,
                        params.step
                    ));
                }
                if let Some(ls) = last_switch {
                    if j - ls <= params.burnin {
                        return Err(format!(
                            "switches at {ls} and {j} violate burnin {}",
                            params.burnin
                        ));
                    }
                }
                last_switch = Some(j);
            }
            prev_k = k;
        }
        Ok(())
    });
}

/// The event queue must dequeue any schedule in non-decreasing time order
/// and preserve FIFO among ties.
#[test]
fn prop_event_queue_orders_any_schedule() {
    let gen = VecF64 { min_len: 1, max_len: 128, lo: 0.0, hi: 1000.0 };
    runner().check("event_queue_order", &gen, |times| {
        let mut q = EventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.schedule_at(t, i);
        }
        let mut last = f64::NEG_INFINITY;
        let mut popped = 0usize;
        while let Some(ev) = q.pop() {
            if ev.time < last {
                return Err(format!("time went backwards: {last} -> {}", ev.time));
            }
            last = ev.time;
            popped += 1;
        }
        if popped != times.len() {
            return Err("lost events".into());
        }
        Ok(())
    });
}

/// Theorem-1 switching times are monotone for ANY valid parameter set.
#[test]
fn prop_switching_times_monotone() {
    let gen = Pair(
        UsizeRange { lo: 2, hi: 40 },              // n
        UsizeRange { lo: 1, hi: 1_000_000 },       // scaled f0_err
    );
    runner().check("theorem1_monotone", &gen, |&(n, f0x)| {
        let params = BoundParams {
            eta: 0.001,
            l: 2.0,
            c: 1.0,
            sigma2: 10.0,
            s: 10,
            f0_err: f0x as f64 / 100.0,
        };
        let bound = ErrorBound::new(params, OrderStats::exponential(n, 1.0));
        let sw = switching_times(&bound);
        if sw.len() != n - 1 {
            return Err(format!("expected {} switches, got {}", n - 1, sw.len()));
        }
        for w in sw.windows(2) {
            if w[1].time < w[0].time - 1e-9 {
                return Err(format!("switch times decrease: {w:?}"));
            }
            if w[1].error > w[0].error + 1e-9 {
                return Err(format!("switch errors increase: {w:?}"));
            }
        }
        Ok(())
    });
}

/// FixedK is truly constant regardless of observations.
#[test]
fn prop_fixed_k_is_constant() {
    let gen = Pair(
        UsizeRange { lo: 1, hi: 64 },
        UsizeRange { lo: 0, hi: 10_000 },
    );
    runner().check("fixed_k_constant", &gen, |&(k, jitter)| {
        let mut p = FixedK::new(k);
        for j in 0..50u64 {
            let got = p.next_k(&IterationObs {
                iteration: j,
                time: (jitter as f64) * j as f64,
                k_used: k,
                grad_inner_prev: Some(if j % 2 == 0 { -1.0 } else { 1.0 }),
                grad_norm_sq: jitter as f64,
            });
            if got != k {
                return Err(format!("fixed k changed to {got}"));
            }
        }
        Ok(())
    });
}

/// Order-statistic means are monotone in k for every delay model we ship.
#[test]
fn prop_order_stats_monotone_across_models() {
    use adasgd::straggler::*;
    let models: Vec<Box<dyn DelayModel>> = vec![
        Box::new(ExponentialDelays::new(1.0)),
        Box::new(ShiftedExponentialDelays::new(0.5, 2.0)),
        Box::new(ParetoDelays::new(1.0, 2.5)),
        Box::new(WeibullDelays::new(1.0, 0.8)),
        Box::new(BimodalDelays::new(1.0, 2, 5.0, 0.1)),
    ];
    for m in &models {
        let os = OrderStats::monte_carlo(m.as_ref(), 12, 4000, 7);
        for k in 2..=12 {
            assert!(
                os.mean(k) >= os.mean(k - 1),
                "{}: mu_{k} < mu_{}",
                m.name(),
                k - 1
            );
        }
    }
}

/// JSON parser round-trips machine-generated manifests of any size.
#[test]
fn prop_json_parses_generated_manifests() {
    use adasgd::config::json::Json;
    let gen = UsizeRange { lo: 0, hi: 40 };
    runner().check("json_manifest", &gen, |&n_entries| {
        let entries: Vec<String> = (0..n_entries)
            .map(|i| {
                format!(
                    r#"{{"name": "a{i}", "file": "a{i}.hlo.txt",
                        "inputs": [{{"shape": [{i}, 7], "dtype": "float32"}}],
                        "outputs": [], "meta": {{"kind": "k{i}", "s": {i}}}}}"#
                )
            })
            .collect();
        let doc = format!(
            r#"{{"version": 1, "entries": [{}]}}"#,
            entries.join(",")
        );
        let parsed = Json::parse(&doc).map_err(|e| e.to_string())?;
        let arr = parsed
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or("no entries")?;
        if arr.len() != n_entries {
            return Err(format!("lost entries: {} != {n_entries}", arr.len()));
        }
        Ok(())
    });
}
