//! Property-based tests over coordinator invariants, via the in-repo
//! `proptest_lite` harness (no proptest crate offline).

use adasgd::master::fastest_k_select;
use adasgd::policy::{AdaptivePflug, FixedK, IterationObs, KPolicy, PflugParams};
use adasgd::proptest_lite::{Gen, Pair, Runner, UsizeRange, VecF64};
use adasgd::rng::{Pcg64, Rng};
use adasgd::sim::EventQueue;
use adasgd::stats::OrderStats;
use adasgd::theory::{switching_times, BoundParams, ErrorBound};

fn runner() -> Runner {
    Runner { cases: 200, seed: 0xADA5, max_shrinks: 100 }
}

/// fastest_k_select must return exactly the k-th order statistic and the
/// set of the k smallest entries, for any delays and any valid k.
#[test]
fn prop_fastest_k_select_matches_sort() {
    let gen = Pair(
        VecF64 { min_len: 1, max_len: 64, lo: 0.001, hi: 100.0 },
        UsizeRange { lo: 0, hi: 1_000_000 },
    );
    runner().check("fastest_k_select", &gen, |(delays, kraw)| {
        let n = delays.len();
        let k = 1 + kraw % n;
        let mut idx = Vec::new();
        let (x_k, _) = fastest_k_select(delays, k, &mut idx);
        let mut sorted = delays.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        if (x_k - sorted[k - 1]).abs() > 1e-12 {
            return Err(format!("x_k {} != sorted[k-1] {}", x_k, sorted[k - 1]));
        }
        let mut chosen: Vec<f64> = idx[..k].iter().map(|&i| delays[i]).collect();
        chosen.sort_by(|a, b| a.total_cmp(b));
        for (c, s) in chosen.iter().zip(&sorted[..k]) {
            if (c - s).abs() > 1e-12 {
                return Err(format!("selected set mismatch: {chosen:?}"));
            }
        }
        // No duplicate worker indices.
        let mut ids: Vec<usize> = idx[..k].to_vec();
        ids.sort_unstable();
        ids.dedup();
        if ids.len() != k {
            return Err("duplicate worker in selection".into());
        }
        Ok(())
    });
}

/// AdaptivePflug: k is monotone non-decreasing, within [k0, k_max], moves
/// only in multiples of `step`, and switches are separated by > burnin.
#[test]
fn prop_adaptive_pflug_state_machine() {
    let gen = Pair(
        UsizeRange { lo: 2, hi: 64 },  // n
        UsizeRange { lo: 0, hi: u32::MAX as usize }, // sign-pattern seed
    );
    runner().check("pflug_invariants", &gen, |&(n, seed)| {
        let params = PflugParams {
            k0: 1 + seed % n.max(1),
            step: 1 + seed % 7,
            thresh: 1 + (seed % 9) as i64,
            burnin: (seed % 50) as u64,
            k_max: n,
        };
        let params = PflugParams { k0: params.k0.min(n), ..params };
        let mut p = AdaptivePflug::new(n, params);
        let mut rng = Pcg64::seed(seed as u64);
        let mut prev_k = p.initial_k();
        let mut last_switch: Option<u64> = None;
        for j in 0..2000u64 {
            let inner = if rng.next_f64() < 0.6 { -1.0 } else { 1.0 };
            let k = p.next_k(&IterationObs {
                iteration: j,
                time: j as f64,
                k_used: prev_k,
                grad_inner_prev: if j == 0 { None } else { Some(inner) },
                grad_norm_sq: 1.0,
            });
            if k < prev_k {
                return Err(format!("k decreased: {prev_k} -> {k} at j={j}"));
            }
            if k > params.k_max {
                return Err(format!("k={k} above k_max={}", params.k_max));
            }
            if k != prev_k {
                if (k - prev_k) != params.step {
                    return Err(format!(
                        "switch moved by {} not step={}",
                        k - prev_k,
                        params.step
                    ));
                }
                if let Some(ls) = last_switch {
                    if j - ls <= params.burnin {
                        return Err(format!(
                            "switches at {ls} and {j} violate burnin {}",
                            params.burnin
                        ));
                    }
                }
                last_switch = Some(j);
            }
            prev_k = k;
        }
        Ok(())
    });
}

/// The event queue must dequeue any schedule in non-decreasing time order
/// and preserve FIFO among ties.
#[test]
fn prop_event_queue_orders_any_schedule() {
    let gen = VecF64 { min_len: 1, max_len: 128, lo: 0.0, hi: 1000.0 };
    runner().check("event_queue_order", &gen, |times| {
        let mut q = EventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.schedule_at(t, i);
        }
        let mut last = f64::NEG_INFINITY;
        let mut popped = 0usize;
        while let Some(ev) = q.pop() {
            if ev.time < last {
                return Err(format!("time went backwards: {last} -> {}", ev.time));
            }
            last = ev.time;
            popped += 1;
        }
        if popped != times.len() {
            return Err("lost events".into());
        }
        Ok(())
    });
}

/// Theorem-1 switching times are monotone for ANY valid parameter set.
#[test]
fn prop_switching_times_monotone() {
    let gen = Pair(
        UsizeRange { lo: 2, hi: 40 },              // n
        UsizeRange { lo: 1, hi: 1_000_000 },       // scaled f0_err
    );
    runner().check("theorem1_monotone", &gen, |&(n, f0x)| {
        let params = BoundParams {
            eta: 0.001,
            l: 2.0,
            c: 1.0,
            sigma2: 10.0,
            s: 10,
            f0_err: f0x as f64 / 100.0,
        };
        let bound = ErrorBound::new(params, OrderStats::exponential(n, 1.0));
        let sw = switching_times(&bound);
        if sw.len() != n - 1 {
            return Err(format!("expected {} switches, got {}", n - 1, sw.len()));
        }
        for w in sw.windows(2) {
            if w[1].time < w[0].time - 1e-9 {
                return Err(format!("switch times decrease: {w:?}"));
            }
            if w[1].error > w[0].error + 1e-9 {
                return Err(format!("switch errors increase: {w:?}"));
            }
        }
        Ok(())
    });
}

/// FixedK is truly constant regardless of observations.
#[test]
fn prop_fixed_k_is_constant() {
    let gen = Pair(
        UsizeRange { lo: 1, hi: 64 },
        UsizeRange { lo: 0, hi: 10_000 },
    );
    runner().check("fixed_k_constant", &gen, |&(k, jitter)| {
        let mut p = FixedK::new(k);
        for j in 0..50u64 {
            let got = p.next_k(&IterationObs {
                iteration: j,
                time: (jitter as f64) * j as f64,
                k_used: k,
                grad_inner_prev: Some(if j % 2 == 0 { -1.0 } else { 1.0 }),
                grad_norm_sq: jitter as f64,
            });
            if got != k {
                return Err(format!("fixed k changed to {got}"));
            }
        }
        Ok(())
    });
}

/// Order-statistic means are monotone in k for every delay model we ship.
#[test]
fn prop_order_stats_monotone_across_models() {
    use adasgd::straggler::*;
    let models: Vec<Box<dyn DelayModel>> = vec![
        Box::new(ExponentialDelays::new(1.0)),
        Box::new(ShiftedExponentialDelays::new(0.5, 2.0)),
        Box::new(ParetoDelays::new(1.0, 2.5)),
        Box::new(WeibullDelays::new(1.0, 0.8)),
        Box::new(BimodalDelays::new(1.0, 2, 5.0, 0.1)),
    ];
    for m in &models {
        let os = OrderStats::monte_carlo(m.as_ref(), 12, 4000, 7);
        for k in 2..=12 {
            assert!(
                os.mean(k) >= os.mean(k - 1),
                "{}: mu_{k} < mu_{}",
                m.name(),
                k - 1
            );
        }
    }
}

/// JSON parser round-trips machine-generated manifests of any size.
#[test]
fn prop_json_parses_generated_manifests() {
    use adasgd::config::json::Json;
    let gen = UsizeRange { lo: 0, hi: 40 };
    runner().check("json_manifest", &gen, |&n_entries| {
        let entries: Vec<String> = (0..n_entries)
            .map(|i| {
                format!(
                    r#"{{"name": "a{i}", "file": "a{i}.hlo.txt",
                        "inputs": [{{"shape": [{i}, 7], "dtype": "float32"}}],
                        "outputs": [], "meta": {{"kind": "k{i}", "s": {i}}}}}"#
                )
            })
            .collect();
        let doc = format!(
            r#"{{"version": 1, "entries": [{}]}}"#,
            entries.join(",")
        );
        let parsed = Json::parse(&doc).map_err(|e| e.to_string())?;
        let arr = parsed
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or("no entries")?;
        if arr.len() != n_entries {
            return Err(format!("lost entries: {} != {n_entries}", arr.len()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Compressor round-trip properties (comm subsystem).
//
// The contract every scheme must keep: `decode(encode(g))` plus the error-
// feedback residual reconstructs `g` — exactly for the sparsifiers (kept
// coordinates are bitwise, dropped ones land whole in the residual), and
// within the QSGD quantization bound `‖g‖₂ / s` per coordinate for the
// stochastic quantizer. Sizes must match the data-independent size model.
// ---------------------------------------------------------------------------

use adasgd::comm::{
    Compressor, Dense, ErrorFeedback, QuantizeQsgd, RandK, TopK,
};

fn grad_gen() -> VecF64 {
    VecF64 { min_len: 1, max_len: 96, lo: -40.0, hi: 40.0 }
}

fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

/// Apply `c` through a fresh error-feedback accumulator; return
/// (decoded, residual, bytes).
fn round_trip(
    c: &mut dyn Compressor,
    g: &[f32],
    seed: u64,
) -> (Vec<f32>, Vec<f32>, u64) {
    let mut rng = Pcg64::seed(seed);
    let mut out = vec![0.0f32; g.len()];
    let bytes = c.apply(g, &mut out, &mut rng);
    let mut fb = ErrorFeedback::new(1);
    fb.update(0, g, &out);
    (out, fb.residual(0).to_vec(), bytes)
}

#[test]
fn prop_dense_round_trip_is_bitwise() {
    runner().check("dense_roundtrip", &grad_gen(), |v| {
        let g = to_f32(v);
        let mut c = Dense::new();
        let (out, resid, bytes) = round_trip(&mut c, &g, 1);
        if out != g {
            return Err("dense must be the identity".into());
        }
        if resid.iter().any(|&r| r != 0.0) {
            return Err("dense residual must be zero".into());
        }
        if bytes != c.encoded_bytes(g.len()) {
            return Err(format!(
                "size model mismatch: {bytes} != {}",
                c.encoded_bytes(g.len())
            ));
        }
        Ok(())
    });
}

/// Shared exact-reconstruction check for the sparsifiers.
fn sparsifier_round_trip_exact(
    c: &mut dyn Compressor,
    expected_nnz: usize,
    g: &[f32],
    seed: u64,
) -> Result<(), String> {
    let (out, resid, bytes) = round_trip(c, g, seed);
    if bytes != c.encoded_bytes(g.len()) {
        return Err(format!(
            "size model mismatch: {bytes} != {}",
            c.encoded_bytes(g.len())
        ));
    }
    let mut kept = 0usize;
    for i in 0..g.len() {
        // Each coordinate is either transmitted bitwise or dropped whole.
        if out[i] != 0.0 || (g[i] == 0.0 && resid[i] == 0.0) {
            if out[i] != 0.0 && out[i] != g[i] {
                return Err(format!(
                    "coord {i}: kept value {} != input {}",
                    out[i], g[i]
                ));
            }
        }
        // decode(encode(g)) + residual == g, exactly (f32 equality).
        if out[i] + resid[i] != g[i] {
            return Err(format!(
                "coord {i}: {} + {} != {}",
                out[i], resid[i], g[i]
            ));
        }
        if out[i] != 0.0 {
            kept += 1;
        }
    }
    // Zeros among the top magnitudes can deflate the count; only assert
    // the upper bound plus exactness above.
    if kept > expected_nnz {
        return Err(format!("kept {kept} > nnz {expected_nnz}"));
    }
    Ok(())
}

#[test]
fn prop_topk_round_trip_is_exact() {
    let gen = Pair(grad_gen(), UsizeRange { lo: 1, hi: 100 });
    runner().check("topk_roundtrip", &gen, |(v, pct)| {
        let g = to_f32(v);
        let frac = *pct as f64 / 100.0;
        let mut c = TopK::new(frac);
        let nnz = c.nnz(g.len());
        sparsifier_round_trip_exact(&mut c, nnz, &g, 2)
    });
}

#[test]
fn prop_randk_round_trip_is_exact() {
    let gen = Pair(grad_gen(), UsizeRange { lo: 1, hi: 100 });
    runner().check("randk_roundtrip", &gen, |(v, pct)| {
        let g = to_f32(v);
        let frac = *pct as f64 / 100.0;
        let mut c = RandK::new(frac);
        let nnz = c.nnz(g.len());
        // Different seeds per case come from the value itself.
        sparsifier_round_trip_exact(&mut c, nnz, &g, 3 + g.len() as u64)
    });
}

#[test]
fn prop_qsgd_round_trip_is_within_the_quantization_bound() {
    let gen = Pair(grad_gen(), UsizeRange { lo: 1, hi: 64 });
    runner().check("qsgd_roundtrip", &gen, |(v, levels)| {
        let g = to_f32(v);
        let s = *levels as u32;
        let mut c = QuantizeQsgd::new(s);
        let (out, resid, bytes) = round_trip(&mut c, &g, 5);
        if bytes != c.encoded_bytes(g.len()) {
            return Err(format!(
                "size model mismatch: {bytes} != {}",
                c.encoded_bytes(g.len())
            ));
        }
        let norm =
            g.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        // Per-coordinate quantization bound, with f32 rounding headroom.
        let bound = norm / s as f64 + 1e-4 * norm + 1e-6;
        for i in 0..g.len() {
            let err = ((out[i] as f64) - (g[i] as f64)).abs();
            if err > bound {
                return Err(format!(
                    "coord {i}: |{} - {}| = {err} > {bound} (s={s})",
                    out[i], g[i]
                ));
            }
            // The residual is what feedback will replay: out + resid must
            // reconstruct g to f32 rounding.
            let recon = out[i] + resid[i];
            let tol = (g[i].abs() + out[i].abs()) * f32::EPSILON * 4.0;
            if (recon - g[i]).abs() > tol {
                return Err(format!(
                    "coord {i}: reconstruction {recon} != {} (tol {tol})",
                    g[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_encoded_bytes_are_data_independent() {
    let gen = UsizeRange { lo: 1, hi: 256 };
    runner().check("size_model", &gen, |&d| {
        let zeros = vec![0.0f32; d];
        let spiky: Vec<f32> =
            (0..d).map(|i| if i % 7 == 0 { 1e6 } else { -3.0 }).collect();
        for mut c in [
            Box::new(Dense::new()) as Box<dyn Compressor>,
            Box::new(TopK::new(0.1)),
            Box::new(RandK::new(0.1)),
            Box::new(QuantizeQsgd::new(4)),
        ] {
            let mut rng = Pcg64::seed(7);
            let mut out = vec![0.0f32; d];
            let b0 = c.apply(&zeros, &mut out, &mut rng);
            let b1 = c.apply(&spiky, &mut out, &mut rng);
            if b0 != b1 || b0 != c.encoded_bytes(d) {
                return Err(format!(
                    "{}: sizes vary with data: {b0} vs {b1} (model {})",
                    c.name(),
                    c.encoded_bytes(d)
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Downlink broadcast + shared-ingress invariants (PR 2). The broadcast
// must reconstruct the model exactly for the dense default, track it
// within the master-side residual for compressed deltas, and the FIFO
// ingress round time must dominate the independent-upload round time,
// collapsing to it exactly when the capacity is unlimited.
// ---------------------------------------------------------------------------

use adasgd::comm::{Broadcast, DownlinkMode, IngressModel, LinkModel};

fn model_gen() -> VecF64 {
    VecF64 { min_len: 1, max_len: 64, lo: -20.0, hi: 20.0 }
}

#[test]
fn prop_free_broadcast_reconstructs_bitwise() {
    runner().check("broadcast_dense", &model_gen(), |v| {
        let w = to_f32(v);
        let mut b = Broadcast::free(4);
        let mut out = vec![0.0f32; w.len()];
        let mut rng = Pcg64::seed(11);
        // Repeated pushes of evolving models all reconstruct exactly.
        for step in 0..4u32 {
            let cur: Vec<f32> =
                w.iter().map(|x| x + step as f32 * 0.25).collect();
            let bytes = b.push(&cur, &mut out, &mut rng);
            if out != cur {
                return Err(format!("push {step}: view is not bitwise"));
            }
            if bytes != b.message_bytes(w.len()) {
                return Err("size model mismatch".into());
            }
            for i in 0..4 {
                if b.download_delay(i, bytes) != 0.0 {
                    return Err("free link charged a download".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_delta_broadcast_view_lag_is_the_residual() {
    // For drop-based delta compression the telescoping identity
    // `w − view == residual` holds to f32 rounding after every push.
    let gen = Pair(model_gen(), UsizeRange { lo: 10, hi: 90 });
    runner().check("broadcast_delta", &gen, |(v, pct)| {
        let w0 = to_f32(v);
        let frac = *pct as f64 / 100.0;
        let mut b = Broadcast::new(
            Box::new(TopK::new(frac)),
            LinkModel::zero_cost(1),
            DownlinkMode::Delta,
        );
        let mut rng = Pcg64::seed(13);
        let mut out = vec![0.0f32; w0.len()];
        let b0 = b.push(&w0, &mut out, &mut rng);
        if out != w0 {
            return Err("bootstrap must ship the model exactly".into());
        }
        if b0 != adasgd::comm::WireFormat::default().dense(w0.len()) {
            return Err("bootstrap must be priced dense".into());
        }
        let mut w = w0;
        for step in 0..6 {
            for (i, x) in w.iter_mut().enumerate() {
                *x += (((step * 13 + i * 7) % 11) as f32 - 5.0) * 0.05;
            }
            b.push(&w, &mut out, &mut rng);
            let gap_sq: f64 = w
                .iter()
                .zip(&out)
                .map(|(a, c)| ((a - c) as f64).powi(2))
                .sum();
            let resid = b.residual_norm_sq();
            if (gap_sq - resid).abs() > 1e-3 * (1.0 + resid) {
                return Err(format!(
                    "step {step}: view gap {gap_sq} != residual {resid}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_congested_round_dominates_independent_round() {
    let gen = Pair(
        VecF64 { min_len: 1, max_len: 40, lo: 0.01, hi: 50.0 },
        Pair(
            UsizeRange { lo: 1, hi: 4096 },    // message bytes
            UsizeRange { lo: 1, hi: 100_000 }, // capacity (scaled below)
        ),
    );
    runner().check("ingress_invariant", &gen, |(arrivals, (bytes, cap))| {
        let bytes = *bytes as u64;
        let capacity = *cap as f64 / 10.0;
        let independent = arrivals
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        // Unlimited capacity reproduces the independent model exactly.
        let mut a = arrivals.clone();
        let free = IngressModel::unlimited().round_completion(&mut a, bytes);
        if free != independent {
            return Err(format!(
                "unlimited ingress changed the clock: {free} vs {independent}"
            ));
        }
        // Finite capacity strictly exceeds it (bytes > 0 always here)...
        let ing = IngressModel::new(capacity);
        let mut a = arrivals.clone();
        let congested = ing.round_completion(&mut a, bytes);
        if congested <= independent {
            return Err(format!(
                "congested {congested} must exceed independent {independent}"
            ));
        }
        // ...by at least one service time, and by at most a full
        // serialization of the round.
        let per = bytes as f64 / capacity;
        let k = arrivals.len() as f64;
        if congested < independent + per - 1e-9 {
            return Err("last message must still be served".into());
        }
        if congested > independent + k * per + 1e-9 {
            return Err("worse than full serialization".into());
        }
        // Monotone in capacity: doubling the capacity cannot slow it.
        let mut a = arrivals.clone();
        let faster =
            IngressModel::new(capacity * 2.0).round_completion(&mut a, bytes);
        if faster > congested + 1e-12 {
            return Err(format!(
                "more capacity slowed the round: {faster} > {congested}"
            ));
        }
        Ok(())
    });
}

/// FIFO store-and-forward and processor sharing are both work-conserving
/// over equal-sized messages, so the completion of a round's *last*
/// message — the sync drivers' round clock — is discipline-invariant
/// (PR 3). PS differs only in per-message completions, which the async
/// engine path observes.
#[test]
fn prop_ps_and_fifo_agree_on_the_round_makespan() {
    use adasgd::comm::IngressDiscipline;
    let gen = Pair(
        VecF64 { min_len: 1, max_len: 40, lo: 0.01, hi: 50.0 },
        Pair(
            UsizeRange { lo: 1, hi: 4096 },    // message bytes
            UsizeRange { lo: 1, hi: 100_000 }, // capacity (scaled below)
        ),
    );
    runner().check("ps_fifo_makespan", &gen, |(arrivals, (bytes, cap))| {
        let bytes = *bytes as u64;
        let capacity = *cap as f64 / 10.0;
        let mut a = arrivals.clone();
        let fifo =
            IngressModel::new(capacity).round_completion(&mut a, bytes);
        let mut a = arrivals.clone();
        let ps = IngressModel::with_discipline(
            capacity,
            IngressDiscipline::Ps,
        )
        .round_completion(&mut a, bytes);
        let scale = fifo.abs().max(1.0);
        if (fifo - ps).abs() > 1e-9 * scale {
            return Err(format!(
                "work conservation violated: fifo {fifo} vs ps {ps} for \
                 {arrivals:?}"
            ));
        }
        // PS must also dominate the independent round time.
        let independent =
            arrivals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if ps < independent - 1e-9 * scale {
            return Err(format!(
                "ps finished before the last arrival: {ps} < {independent}"
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Gradient-coding decode properties (PR 4).
// ---------------------------------------------------------------------

use adasgd::coding::{
    BernoulliScheme, CodingScheme, CoverPart, CyclicRepetition, FrcScheme,
};

/// Every placement family instantiable at (n, r); frc only when r | n.
fn schemes_for(n: usize, r: usize, seed: u64) -> Vec<Box<dyn CodingScheme>> {
    let mut out: Vec<Box<dyn CodingScheme>> = vec![
        Box::new(CyclicRepetition::new(n, r).expect("valid cyclic")),
        Box::new(BernoulliScheme::new(n, r, seed).expect("valid bernoulli")),
    ];
    if n % r == 0 {
        out.push(Box::new(FrcScheme::new(n, r).expect("valid frc")));
    }
    out
}

/// A random responder subset of the given size, order shuffled (decode
/// must not depend on seeing responders sorted).
fn random_subset(n: usize, size: usize, rng: &mut Pcg64) -> Vec<usize> {
    let mut workers: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut workers);
    workers.truncate(size);
    workers
}

fn check_cover(
    scheme: &dyn CodingScheme,
    responders: &[usize],
    parts: &[CoverPart],
) -> Result<(), String> {
    let n = scheme.n();
    let mut covered: Vec<usize> =
        parts.iter().flat_map(|p| p.shards.clone()).collect();
    covered.sort_unstable();
    if covered != (0..n).collect::<Vec<_>>() {
        return Err(format!(
            "{}: cover is not each shard exactly once: {covered:?}",
            scheme.name()
        ));
    }
    for part in parts {
        if part.shards.is_empty() {
            return Err(format!("{}: empty part", scheme.name()));
        }
        if !responders.contains(&part.worker) {
            return Err(format!(
                "{}: part worker {} never responded",
                scheme.name(),
                part.worker
            ));
        }
        for &s in &part.shards {
            if !scheme.assignment(part.worker).contains(&s) {
                return Err(format!(
                    "{}: worker {} does not hold shard {s}",
                    scheme.name(),
                    part.worker
                ));
            }
        }
    }
    Ok(())
}

/// Whenever decode succeeds, the cover holds every shard exactly once,
/// drawn from the responders' own assignments.
#[test]
fn prop_decode_covers_each_shard_exactly_once() {
    let gen = Pair(
        UsizeRange { lo: 2, hi: 20 },  // n
        UsizeRange { lo: 0, hi: 1 << 20 }, // derive r, size, order
    );
    runner().check("decode_cover", &gen, |&(n, salt)| {
        let mut rng = Pcg64::seed(salt as u64);
        let r = 1 + (rng.next_u64() as usize) % n;
        let size = 1 + (rng.next_u64() as usize) % n;
        for scheme in schemes_for(n, r, salt as u64) {
            let responders = random_subset(n, size, &mut rng);
            if let Some(parts) = scheme.decode(&responders) {
                check_cover(scheme.as_ref(), &responders, &parts)?;
            }
        }
        Ok(())
    });
}

/// Decodability is monotone: adding responders never breaks a decode.
#[test]
fn prop_decodability_is_monotone_in_the_responder_set() {
    let gen = Pair(
        UsizeRange { lo: 2, hi: 20 },
        UsizeRange { lo: 0, hi: 1 << 20 },
    );
    runner().check("decode_monotone", &gen, |&(n, salt)| {
        let mut rng = Pcg64::seed(salt as u64 ^ 0xD1CE);
        let r = 1 + (rng.next_u64() as usize) % n;
        let size = 1 + (rng.next_u64() as usize) % n;
        for scheme in schemes_for(n, r, salt as u64) {
            let responders = random_subset(n, size, &mut rng);
            if scheme.decode(&responders).is_none() {
                continue;
            }
            // Extend by every absent worker, one at a time: still Some.
            for extra in 0..n {
                if responders.contains(&extra) {
                    continue;
                }
                let mut bigger = responders.clone();
                bigger.push(extra);
                if scheme.decode(&bigger).is_none() {
                    return Err(format!(
                        "{}: adding responder {extra} to {responders:?} \
                         broke the decode",
                        scheme.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Every (n − r + 1)-subset decodes, for all three placements — for
/// cyclic this is the ISSUE's named guarantee, and exhaustive small-n
/// coverage backs the sampled large-n cases.
#[test]
fn prop_threshold_subsets_always_decode() {
    let gen = Pair(
        UsizeRange { lo: 2, hi: 24 },
        UsizeRange { lo: 0, hi: 1 << 20 },
    );
    runner().check("threshold_decodes", &gen, |&(n, salt)| {
        let mut rng = Pcg64::seed(salt as u64 ^ 0xBEEF);
        let r = 1 + (rng.next_u64() as usize) % n;
        for scheme in schemes_for(n, r, salt as u64) {
            let responders =
                random_subset(n, scheme.recovery_threshold(), &mut rng);
            let parts = scheme.decode(&responders).ok_or_else(|| {
                format!(
                    "{}: threshold subset {responders:?} failed to decode",
                    scheme.name()
                )
            })?;
            check_cover(scheme.as_ref(), &responders, &parts)?;
        }
        Ok(())
    });
}

/// CyclicRepetition decodes from *every* (n − r + 1)-subset: exhaustive
/// over all subsets for n ≤ 10, every r.
#[test]
fn cyclic_decodes_from_every_threshold_subset_exhaustively() {
    for n in 2usize..=10 {
        for r in 1..=n {
            let scheme = CyclicRepetition::new(n, r).unwrap();
            let thr = scheme.recovery_threshold();
            for mask in 0u32..(1u32 << n) {
                if mask.count_ones() as usize != thr {
                    continue;
                }
                let responders: Vec<usize> =
                    (0..n).filter(|&w| mask & (1 << w) != 0).collect();
                let parts =
                    scheme.decode(&responders).unwrap_or_else(|| {
                        panic!("cyclic(n={n}, r={r}): {responders:?}")
                    });
                let mut covered: Vec<usize> = parts
                    .iter()
                    .flat_map(|p| p.shards.clone())
                    .collect();
                covered.sort_unstable();
                assert_eq!(covered, (0..n).collect::<Vec<_>>());
            }
        }
    }
}
