//! CLI integration: drive the built `adasgd` binary end-to-end.

use std::process::Command;

fn adasgd() -> Command {
    Command::new(env!("CARGO_BIN_EXE_adasgd"))
}

fn run_ok(args: &[&str]) -> String {
    let out = adasgd().args(args).output().expect("spawn adasgd");
    assert!(
        out.status.success(),
        "adasgd {:?} failed:\nstdout: {}\nstderr: {}",
        args,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn help_lists_commands() {
    let text = run_ok(&["help"]);
    for cmd in ["fig1", "fig2", "fig3", "train", "train-transformer", "trace"]
    {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn unknown_command_fails_cleanly() {
    let out = adasgd().arg("bogus").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn switching_times_prints_schedule() {
    let text = run_ok(&["switching-times"]);
    assert!(text.contains("switch to k=2"));
    assert!(text.contains("switch to k=5"));
}

#[test]
fn fig1_writes_csv() {
    let dir = std::env::temp_dir().join("adasgd_cli_fig1");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("fig1.csv");
    let text = run_ok(&[
        "fig1",
        "--points",
        "50",
        "--quiet",
        "--out",
        csv.to_str().unwrap(),
    ]);
    assert!(text.contains("Theorem-1 switching times"));
    let body = std::fs::read_to_string(&csv).unwrap();
    let mut lines = body.lines();
    assert!(lines.next().unwrap().starts_with("# adasgd run series"));
    assert_eq!(
        lines.next().unwrap(),
        "label,iteration,time,k,error,bytes,comm_time,bytes_down,\
         down_time,late_responses,mean_staleness"
    );
    // Comment + header, then 5 fixed curves + adaptive, 50 points each.
    assert_eq!(body.lines().count(), 2 + 6 * 50);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_quick_run_reports_error() {
    let dir = std::env::temp_dir().join("adasgd_cli_train");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("train.csv");
    let text = run_ok(&[
        "train",
        "--n",
        "10",
        "--m",
        "200",
        "--d",
        "10",
        "--k",
        "5",
        "--eta",
        "0.002",
        "--max-iterations",
        "300",
        "--max-time",
        "0",
        "--quiet",
        "--out",
        csv.to_str().unwrap(),
    ]);
    assert!(text.contains("300 steps"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_rejects_bad_partition() {
    let out = adasgd()
        .args(["train", "--n", "7", "--m", "200", "--d", "5", "--quiet"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("divide"));
}

#[test]
fn train_from_toml_config() {
    let dir = std::env::temp_dir().join("adasgd_cli_toml");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.toml");
    std::fs::write(
        &cfg,
        r#"
label = "toml-run"
n = 10
eta = 0.002
max_iterations = 200
max_time = 0.0

[delays]
kind = "exponential"
lambda = 1.0

[policy]
kind = "fixed"
k = 4

[workload]
kind = "linreg"
m = 200
d = 10
"#,
    )
    .unwrap();
    let csv = dir.join("out.csv");
    let text = run_ok(&[
        "train",
        "--config",
        cfg.to_str().unwrap(),
        "--quiet",
        "--out",
        csv.to_str().unwrap(),
    ]);
    assert!(text.contains("toml-run"), "{text}");
    let body = std::fs::read_to_string(&csv).unwrap();
    assert!(body.contains("toml-run,"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_with_topk_comm_reports_bytes() {
    let dir = std::env::temp_dir().join("adasgd_cli_comm");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("comm.csv");
    let text = run_ok(&[
        "train",
        "--n",
        "10",
        "--m",
        "200",
        "--d",
        "10",
        "--k",
        "5",
        "--eta",
        "0.002",
        "--max-iterations",
        "200",
        "--max-time",
        "0",
        "--comm",
        "topk",
        "--comm-frac",
        "0.3",
        "--bandwidth",
        "100",
        "--quiet",
        "--out",
        csv.to_str().unwrap(),
    ]);
    // 3-of-10 coords -> 40 bytes per message, 200 iterations x k=5.
    assert!(text.contains("40000 bytes up"), "{text}");
    let body = std::fs::read_to_string(&csv).unwrap();
    // The final recorded sample carries the cumulative byte count.
    assert!(body.contains(",40000,"), "{body}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_with_priced_downlink_and_ingress_reports_downlink_bytes() {
    let dir = std::env::temp_dir().join("adasgd_cli_bidir");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("bidir.csv");
    let text = run_ok(&[
        "train",
        "--n",
        "10",
        "--m",
        "200",
        "--d",
        "10",
        "--k",
        "5",
        "--eta",
        "0.002",
        "--max-iterations",
        "100",
        "--max-time",
        "0",
        "--downlink",
        "topk",
        "--down-frac",
        "0.3",
        "--down-bandwidth",
        "100",
        "--ingress-bw",
        "500",
        "--quiet",
        "--out",
        csv.to_str().unwrap(),
    ]);
    // Delta downlink: dense bootstrap (56 B) + 99 x 40-B deltas, to 10
    // workers each.
    assert!(text.contains("40160 bytes down"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_accepts_ps_ingress_and_per_worker_downlinks() {
    let text = run_ok(&[
        "train",
        "--n",
        "4",
        "--m",
        "200",
        "--d",
        "10",
        "--k",
        "2",
        "--eta",
        "0.002",
        "--max-iterations",
        "50",
        "--max-time",
        "0",
        "--ingress-bw",
        "500",
        "--ingress",
        "ps",
        "--down-bandwidths",
        "100, 200, 0, 50",
        "--quiet",
    ]);
    assert!(text.contains("steps"), "{text}");
    // Heterogeneous finite downlinks charge download time.
    assert!(text.contains("bytes down"), "{text}");
}

#[test]
fn bad_ingress_discipline_and_bandwidth_lists_fail_cleanly() {
    for args in [
        vec!["train", "--n", "4", "--m", "200", "--d", "10", "--ingress", "lifo"],
        vec![
            "train", "--n", "4", "--m", "200", "--d", "10",
            "--down-bandwidths", "1,two,3",
        ],
        // Wrong entry count is a validation error against n.
        vec![
            "train", "--n", "4", "--m", "200", "--d", "10",
            "--down-bandwidths", "1,2",
        ],
    ] {
        let out = adasgd().args(&args).output().unwrap();
        assert!(!out.status.success(), "{args:?} should fail");
    }
}

#[test]
fn unknown_downlink_scheme_fails_cleanly() {
    let out = adasgd()
        .args([
            "train", "--n", "10", "--m", "200", "--d", "10", "--downlink",
            "zip",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("downlink"));
}

#[test]
fn unknown_comm_scheme_fails_cleanly() {
    let out = adasgd()
        .args(["train", "--n", "10", "--m", "200", "--d", "10", "--comm", "zip"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("comm"));
}

#[test]
fn list_artifacts_without_runtime_fails_cleanly() {
    // Without the pjrt feature (the default build) the command must fail
    // with a pointer at the feature, not panic. With pjrt + artifacts
    // present it lists the registry.
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let out = adasgd()
        .args(["list-artifacts", "--artifacts", artifacts])
        .output()
        .unwrap();
    if cfg!(feature = "pjrt") && std::path::Path::new(artifacts).exists() {
        assert!(out.status.success());
        assert!(String::from_utf8_lossy(&out.stdout)
            .contains("linreg_grad_s40_d100"));
    } else {
        assert!(!out.status.success());
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("runtime error"), "{err}");
    }
}

#[test]
fn train_with_coding_runs_and_records_scheme_in_the_csv_header() {
    let dir = std::env::temp_dir().join("adasgd_cli_coding");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("coded.csv");
    let text = run_ok(&[
        "train",
        "--n",
        "10",
        "--m",
        "200",
        "--d",
        "10",
        "--k",
        "9",
        "--coding",
        "frc",
        "--replication",
        "2",
        "--eta",
        "0.002",
        "--max-iterations",
        "100",
        "--max-time",
        "0",
        "--quiet",
        "--out",
        csv.to_str().unwrap(),
    ]);
    assert!(text.contains("100 steps"), "{text}");
    let body = std::fs::read_to_string(&csv).unwrap();
    // The run-header comment records the coding scheme and r.
    assert!(body.contains("# coding: scheme=frc r=2"), "{body}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_record_analyze_dump_replay_round_trip() {
    // The full observability loop through the binary: record a traced
    // run from the committed smoke config (--trace overrides its
    // `[trace] dir` so nothing lands in the repo), analyze and dump the
    // file, then replay it — `trace replay` exits non-zero unless every
    // replayed sample is bitwise-identical to the recording.
    let dir = std::env::temp_dir().join(format!(
        "adasgd_cli_trace_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let dir_s = dir.to_str().unwrap().to_string();
    let csv = dir.join("out.csv");
    let cfg = "examples/trace_smoke.toml";
    let text = run_ok(&[
        "train",
        "--config",
        cfg,
        "--trace",
        &dir_s,
        "--quiet",
        "--out",
        csv.to_str().unwrap(),
    ]);
    assert!(text.contains("event trace written"), "{text}");
    let trace_file = dir.join("trace-smoke.trace");
    assert!(trace_file.exists(), "missing {}", trace_file.display());
    let tf = trace_file.to_str().unwrap();

    let report = run_ok(&["trace", "analyze", tf]);
    assert!(report.contains("trace analysis: trace-smoke"), "{report}");
    assert!(report.contains("worker utilization"), "{report}");
    assert!(report.contains("wait decomposition"), "{report}");

    let dump = run_ok(&["trace", "dump", tf, "--limit", "5"]);
    assert!(dump.contains("trace-smoke"), "{dump}");

    let replay = run_ok(&["trace", "replay", tf, "--config", cfg]);
    assert!(replay.contains("replay OK"), "{replay}");

    // A mismatched config must be rejected, not silently diverge.
    let out = adasgd()
        .args(["trace", "replay", tf, "--config", "examples/missing.toml"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_rejects_frc_replication_not_dividing_n() {
    // Regression: r ∤ n used to panic inside FrcScheme::new; it must be
    // a clean config error pointing at the fix.
    let out = adasgd()
        .args([
            "train", "--n", "10", "--m", "200", "--d", "10", "--coding",
            "frc", "--replication", "3", "--quiet",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("divide"), "{stderr}");
    assert!(stderr.contains("cyclic"), "{stderr}");
    // And the suggested fix works: cyclic takes the same r.
    let dir = std::env::temp_dir().join("adasgd_cli_coding_cyclic");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("cyclic.csv");
    let ok = adasgd()
        .args([
            "train",
            "--n",
            "10",
            "--m",
            "200",
            "--d",
            "10",
            "--coding",
            "cyclic",
            "--replication",
            "3",
            "--max-iterations",
            "50",
            "--max-time",
            "0",
            "--quiet",
            "--out",
            csv.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        ok.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&ok.stderr)
    );
    std::fs::remove_dir_all(&dir).ok();
}
