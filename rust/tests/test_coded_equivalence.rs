//! Coded-gather ↔ engine equivalence.
//!
//! PR 4 retired the standalone coded driver (`coding::run_coded_gd`'s
//! hand-rolled loop) in favour of the engine's `CodedGather` discipline.
//! This file keeps the straight-line coded round loop alive as an
//! executable specification and asserts two contracts:
//!
//! 1. **Spec ≡ engine.** With the wait target fixed at the recovery
//!    threshold, the engine path reproduces the straight-line loop
//!    bit for bit — model, clock, and recorded samples — on the dense
//!    zero-cost channel *and* on comm-priced channels, for all three
//!    placement schemes across seeds.
//! 2. **r = 1 ≡ fastest-k.** With no redundancy the only decodable
//!    responder set is all n workers, and `CodedGather` must be
//!    `FastestKGather` at `k = n` bit for bit — including on priced
//!    channels (top-k + error feedback uplink, FIFO ingress, delta
//!    downlink, QSGD).
//!
//! Two normalisations distinguish the spec below from the *pre-refactor*
//! `run_coded_gd` (whose trajectories were pinned only up to tolerances,
//! by `coding/frc.rs` tests that still pass): per-group shard sums now
//! accumulate per contributing message in responder order (the wire
//! model: one message per contributing worker), and the mean is applied
//! as `g/n` before the step rather than fused into it — both are the
//! engine's canonical operation order.

use adasgd::coding::{
    run_coded_comm, run_coded_gd, BernoulliScheme, CodedConfig,
    CodingScheme, CyclicRepetition, FrcScheme,
};
use adasgd::comm::{
    Broadcast, CommChannel, DownlinkMode, IngressModel, LinkModel,
    QuantizeQsgd, TopK,
};
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::engine::{
    CodedGather, EngineConfig, EngineCore, RngStreams, RoundEngine,
};
use adasgd::grad::{GradBackend, NativeBackend};
use adasgd::master::{
    fastest_k_select, run_fastest_k_comm, MasterConfig,
};
use adasgd::metrics::{Recorder, Sample};
use adasgd::model::LinRegProblem;
use adasgd::policy::FixedK;
use adasgd::rng::Pcg64;
use adasgd::straggler::{DelayModel, ExponentialDelays};

/// What the spec loop and the engine paths are compared on.
struct RefRun {
    w: Vec<f32>,
    total_time: f64,
    steps: u64,
    samples: Vec<Sample>,
}

/// The straight-line coded round loop: the executable specification of
/// what `CodedGather` + `RngStreams::coded` must compute when the wait
/// target is the recovery threshold (where decode always succeeds, so
/// the first decodable responder set *is* the threshold set).
fn reference_coded(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    scheme: &dyn CodingScheme,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &CodedConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> RefRun {
    let n = scheme.n();
    assert_eq!(backend.n_shards(), n);
    let d = backend.dim();
    let threshold = scheme.recovery_threshold();
    let r = scheme.r() as f64;

    let mut rng = Pcg64::seed_stream(cfg.seed, 0xC0DE);
    let mut bcast_rng = Pcg64::seed_stream(cfg.seed, 0xB050);
    let mut comm_rng = Pcg64::seed_stream(cfg.seed, 0xC047);
    let bytes0 = channel.stats.bytes_sent;
    let comm_t0 = channel.stats.comm_time;
    let down0 = channel.stats.bytes_down;
    let down_t0 = channel.stats.down_time;
    let msg_bytes = channel.message_bytes(d);
    let ingress = *channel.ingress();

    let mut w = w0.to_vec();
    let mut w_view = w0.to_vec();
    let mut g = vec![0.0f32; d];
    let mut partial = vec![0.0f32; d];
    let mut message = vec![0.0f32; d];
    let mut decoded = vec![0.0f32; d];
    let mut delay_buf = vec![0.0f64; n];
    let mut idx_buf: Vec<usize> = Vec::with_capacity(n);
    let mut arrival_buf: Vec<f64> = Vec::with_capacity(n);

    let mut recorder =
        Recorder::with_stride("coded-spec", cfg.record_stride);
    recorder.push_forced(Sample {
        iteration: 0,
        time: 0.0,
        k: threshold,
        error: eval_error(&w),
        ..Default::default()
    });

    let mut t = 0.0f64;
    let mut j = 0u64;
    while j < cfg.max_iterations
        && (cfg.max_time <= 0.0 || t < cfg.max_time)
    {
        backend.on_iteration(j);
        let down_bytes =
            channel.broadcast_model(&w, &mut w_view, &mut bcast_rng);
        for (i, slot) in delay_buf.iter_mut().enumerate() {
            // r shards per worker → r× compute per response, plus the
            // priced upload and download.
            *slot = delays.sample(j, i, &mut rng) * r
                + channel.link_upload_delay(i, msg_bytes)
                + channel.download_delay(i, down_bytes);
        }
        let (x_thr, _) =
            fastest_k_select(&delay_buf, threshold, &mut idx_buf);
        let round_time = if ingress.is_unlimited() {
            x_thr
        } else {
            arrival_buf.clear();
            arrival_buf
                .extend(idx_buf[..threshold].iter().map(|&i| delay_buf[i]));
            ingress.round_completion(&mut arrival_buf, msg_bytes)
        };
        t += round_time;

        let cover = scheme
            .decode(&idx_buf[..threshold])
            .expect("threshold responses always decode");
        g.iter_mut().for_each(|v| *v = 0.0);
        for part in &cover {
            let (&first, rest) = part.shards.split_first().unwrap();
            backend.partial_grad(first, &w_view, &mut message);
            for &shard in rest {
                backend.partial_grad(shard, &w_view, &mut partial);
                for (mv, pv) in message.iter_mut().zip(&partial) {
                    *mv += *pv;
                }
            }
            channel.transmit(
                part.worker,
                &message,
                &mut decoded,
                &mut comm_rng,
            );
            for (gv, pv) in g.iter_mut().zip(&decoded) {
                *gv += *pv;
            }
        }
        // Exact full gradient: every shard covered once → mean over n.
        let inv_n = 1.0 / n as f32;
        for gv in g.iter_mut() {
            *gv *= inv_n;
        }
        for (wv, gv) in w.iter_mut().zip(&g) {
            *wv -= cfg.eta * *gv;
        }

        j += 1;
        if j % cfg.record_stride == 0 {
            recorder.push_forced(Sample {
                iteration: j,
                time: t,
                k: threshold,
                error: eval_error(&w),
                bytes: channel.stats.bytes_sent - bytes0,
                comm_time: channel.stats.comm_time - comm_t0,
                bytes_down: channel.stats.bytes_down - down0,
                down_time: channel.stats.down_time - down_t0,
            });
        }
    }
    if j % cfg.record_stride != 0 {
        recorder.push_forced(Sample {
            iteration: j,
            time: t,
            k: threshold,
            error: eval_error(&w),
            bytes: channel.stats.bytes_sent - bytes0,
            comm_time: channel.stats.comm_time - comm_t0,
            bytes_down: channel.stats.bytes_down - down0,
            down_time: channel.stats.down_time - down_t0,
        });
    }

    RefRun {
        w,
        total_time: t,
        steps: j,
        samples: recorder.samples().to_vec(),
    }
}

// ---------------------------------------------------------------------
// Fixtures.
// ---------------------------------------------------------------------

fn setup(seed: u64) -> (NativeBackend, LinRegProblem) {
    let ds = SyntheticDataset::generate(
        SyntheticConfig { m: 200, d: 10, ..Default::default() },
        seed,
    );
    let problem = LinRegProblem::new(&ds);
    (NativeBackend::new(Shards::partition(&ds, 10)), problem)
}

fn delays() -> ExponentialDelays {
    ExponentialDelays::new(1.0)
}

type ChannelFactory = Box<dyn Fn() -> CommChannel>;

/// Index 0 is the dense zero-cost default (the headline bitwise
/// contract); the rest exercise compression + error feedback, finite
/// links, delta downlink, and finite FIFO ingress.
fn channels() -> Vec<(&'static str, ChannelFactory)> {
    vec![
        ("dense-default", Box::new(|| CommChannel::dense(10))),
        (
            "topk-ef-uplink",
            Box::new(|| {
                CommChannel::new(
                    Box::new(TopK::new(0.3)),
                    LinkModel::uniform(10, 400.0, 0.01),
                    true,
                )
            }),
        ),
        (
            "qsgd-delta-ingress",
            Box::new(|| {
                CommChannel::new(
                    Box::new(QuantizeQsgd::new(4)),
                    LinkModel::uniform(10, 800.0, 0.0),
                    true,
                )
                .with_broadcast(Broadcast::new(
                    Box::new(TopK::new(0.5)),
                    LinkModel::uniform(10, 400.0, 0.0),
                    DownlinkMode::Delta,
                ))
                .with_ingress(IngressModel::new(500.0))
            }),
        ),
    ]
}

fn schemes() -> Vec<(&'static str, Box<dyn CodingScheme>)> {
    vec![
        ("frc-r2", Box::new(FrcScheme::new(10, 2).unwrap())),
        ("frc-r5", Box::new(FrcScheme::new(10, 5).unwrap())),
        ("cyclic-r3", Box::new(CyclicRepetition::new(10, 3).unwrap())),
        (
            "bernoulli-r3",
            Box::new(BernoulliScheme::new(10, 3, 77).unwrap()),
        ),
    ]
}

fn assert_runs_equal(tag: &str, reference: &RefRun, engine: &RefRun) {
    assert_eq!(reference.steps, engine.steps, "{tag}: steps");
    assert_eq!(
        reference.w, engine.w,
        "{tag}: final model must be bitwise identical"
    );
    assert_eq!(
        reference.total_time.to_bits(),
        engine.total_time.to_bits(),
        "{tag}: clock must be bitwise identical ({} vs {})",
        reference.total_time,
        engine.total_time
    );
    assert_eq!(
        reference.samples.len(),
        engine.samples.len(),
        "{tag}: sample count"
    );
    for (a, b) in reference.samples.iter().zip(&engine.samples) {
        assert_eq!(a, b, "{tag}: recorded sample mismatch");
    }
}

// ---------------------------------------------------------------------
// Contract 1: spec loop ≡ engine path.
// ---------------------------------------------------------------------

#[test]
fn engine_reproduces_the_coded_spec_on_the_dense_channel() {
    // The legacy shim (run_coded_gd → engine) against the straight-line
    // loop, across ≥ 3 seeds and all placement schemes.
    for seed in [0u64, 1, 7, 23] {
        for (sname, scheme) in schemes() {
            let cfg = CodedConfig {
                eta: 0.002,
                max_iterations: 150,
                max_time: 0.0,
                seed,
                record_stride: 20,
                r: scheme.r(),
            };
            let w0 = vec![0.0f32; 10];
            let reference = {
                let (mut backend, problem) = setup(seed);
                let mut channel = CommChannel::dense(10);
                reference_coded(
                    &mut backend,
                    &delays(),
                    scheme.as_ref(),
                    &mut channel,
                    &w0,
                    &cfg,
                    &mut |w| problem.error(w),
                )
            };
            let engine = {
                let (mut backend, problem) = setup(seed);
                let run = run_coded_gd(
                    &mut backend,
                    &delays(),
                    scheme.as_ref(),
                    &w0,
                    &cfg,
                    &mut |w| problem.error(w),
                );
                RefRun {
                    w: run.w,
                    total_time: run.total_time,
                    steps: run.iterations,
                    samples: run.recorder.samples().to_vec(),
                }
            };
            assert_runs_equal(
                &format!("coded/{sname}/seed{seed}"),
                &reference,
                &engine,
            );
        }
    }
}

#[test]
fn engine_reproduces_the_coded_spec_on_priced_channels() {
    // Same contract with the full bidirectional pricing stack turned
    // on: the spec performs the identical operations in the identical
    // order, so equality stays exact.
    for seed in [3u64, 11] {
        for (cname, make_channel) in channels() {
            let scheme = FrcScheme::new(10, 2).unwrap();
            let threshold = scheme.recovery_threshold();
            let cfg = CodedConfig {
                eta: 0.002,
                max_iterations: 120,
                max_time: 0.0,
                seed,
                record_stride: 20,
                r: 2,
            };
            let mcfg = MasterConfig {
                eta: cfg.eta,
                momentum: 0.0,
                max_iterations: cfg.max_iterations,
                max_time: cfg.max_time,
                seed: cfg.seed,
                record_stride: cfg.record_stride,
                intra_jobs: 1,
            };
            let w0 = vec![0.0f32; 10];
            let reference = {
                let (mut backend, problem) = setup(seed);
                let mut channel = make_channel();
                reference_coded(
                    &mut backend,
                    &delays(),
                    &scheme,
                    &mut channel,
                    &w0,
                    &cfg,
                    &mut |w| problem.error(w),
                )
            };
            let engine = {
                let (mut backend, problem) = setup(seed);
                let mut channel = make_channel();
                let mut policy = FixedK::new(threshold);
                let run = run_coded_comm(
                    &mut backend,
                    &delays(),
                    &scheme,
                    &mut policy,
                    &mut channel,
                    &w0,
                    &mcfg,
                    &mut |w| problem.error(w),
                );
                RefRun {
                    w: run.w,
                    total_time: run.total_time,
                    steps: run.iterations,
                    samples: run.recorder.samples().to_vec(),
                }
            };
            assert_runs_equal(
                &format!("coded-comm/{cname}/seed{seed}"),
                &reference,
                &engine,
            );
        }
    }
}

#[test]
fn coded_spec_respects_a_time_budget() {
    let scheme = FrcScheme::new(10, 2).unwrap();
    let cfg = CodedConfig {
        eta: 0.001,
        max_iterations: u64::MAX / 2,
        max_time: 30.0,
        seed: 5,
        record_stride: 10,
        r: 2,
    };
    let w0 = vec![0.0f32; 10];
    let reference = {
        let (mut backend, problem) = setup(5);
        let mut channel = CommChannel::dense(10);
        reference_coded(
            &mut backend,
            &delays(),
            &scheme,
            &mut channel,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        )
    };
    let engine = {
        let (mut backend, problem) = setup(5);
        let run = run_coded_gd(
            &mut backend,
            &delays(),
            &scheme,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        RefRun {
            w: run.w,
            total_time: run.total_time,
            steps: run.iterations,
            samples: run.recorder.samples().to_vec(),
        }
    };
    assert!(reference.total_time >= 30.0);
    assert_runs_equal("coded/time-budget", &reference, &engine);
}

// ---------------------------------------------------------------------
// Contract 2: r = 1 degenerates to fastest-k at k = n, bit for bit,
// including comm-priced channels.
// ---------------------------------------------------------------------

#[test]
fn coded_r1_is_fastest_k_at_n_bitwise_including_priced_channels() {
    for seed in [0u64, 9, 17] {
        for (cname, make_channel) in channels() {
            let cfg = MasterConfig {
                eta: 0.002,
                max_iterations: 120,
                seed,
                record_stride: 20,
                ..Default::default()
            };
            let w0 = vec![0.0f32; 10];
            // Both sides share the *sync* rng streams so the delay and
            // compression draws line up draw for draw.
            let fastest = {
                let (mut backend, problem) = setup(seed);
                let mut policy = FixedK::new(10);
                let mut channel = make_channel();
                let run = run_fastest_k_comm(
                    &mut backend,
                    &delays(),
                    &mut policy,
                    &mut channel,
                    &w0,
                    &cfg,
                    &mut |w| problem.error(w),
                );
                RefRun {
                    w: run.w,
                    total_time: run.total_time,
                    steps: run.iterations,
                    samples: run.recorder.samples().to_vec(),
                }
            };
            let coded = {
                let (mut backend, problem) = setup(seed);
                let scheme = FrcScheme::new(10, 1).unwrap();
                let mut policy = FixedK::new(10);
                let mut channel = make_channel();
                let mut eval = |w: &[f32]| problem.error(w);
                let engine_cfg = EngineConfig {
                    eta: cfg.eta,
                    momentum: cfg.momentum,
                    max_steps: cfg.max_iterations,
                    max_time: cfg.max_time,
                    seed: cfg.seed,
                    record_stride: cfg.record_stride,
                    intra_jobs: 1,
                };
                let core = EngineCore::new(
                    "coded-r1",
                    &mut channel,
                    &delays(),
                    &mut eval,
                    &w0,
                    engine_cfg,
                    RngStreams::sync(seed),
                );
                let mut gather =
                    CodedGather::new(&mut backend, &scheme, &mut policy);
                let run = RoundEngine::new(core).run(&mut gather);
                RefRun {
                    w: run.w,
                    total_time: run.total_time,
                    steps: run.steps,
                    samples: run.recorder.samples().to_vec(),
                }
            };
            assert_runs_equal(
                &format!("r1-vs-fastest/{cname}/seed{seed}"),
                &fastest,
                &coded,
            );
        }
    }
}
