//! Integration: transformer LM trains through the fastest-k coordinator
//! via the AOT artifacts (the e2e stack proof, small-scale; the full run
//! lives in examples/transformer_e2e.rs and EXPERIMENTS.md).

use adasgd::grad::GradBackend;
use adasgd::master::{run_fastest_k, MasterConfig};
use adasgd::policy::FixedK;
use adasgd::runtime::Runtime;
use adasgd::straggler::ExponentialDelays;
use adasgd::transformer::{TransformerBackend, TransformerSession};
use std::sync::Arc;

fn runtime() -> Arc<Runtime> {
    let dir = std::env::var("ADASGD_ARTIFACTS")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into());
    Runtime::open(&dir).expect("run `make artifacts` first")
}

#[test]
fn init_params_deterministic_and_sized() {
    let rt = runtime();
    let session = TransformerSession::new(&rt, "tiny", 0).expect("session");
    let p1 = session.init_params(7).expect("init");
    let p2 = session.init_params(7).expect("init");
    assert_eq!(p1.len(), session.params());
    assert_eq!(p1, p2, "same seed must give identical params");
    let p3 = session.init_params(8).expect("init");
    assert_ne!(p1, p3);
}

#[test]
fn fused_step_decreases_loss() {
    let rt = runtime();
    let session = TransformerSession::new(&rt, "tiny", 3).expect("session");
    let mut params = session.init_params(1).expect("init");
    let mut losses = Vec::new();
    for j in 0..12 {
        losses.push(session.step(&mut params, 0.05, j).expect("step"));
    }
    assert!(
        losses.last().unwrap() < &(losses[0] - 0.2),
        "loss must drop: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn grad_backend_matches_step_semantics() {
    // One fastest-n iteration with the grad artifact + host apply must
    // track the fused step (same batch, same eta) closely.
    let rt = runtime();
    let session = TransformerSession::new(&rt, "tiny", 5).expect("session");
    let mut backend = TransformerBackend::new(&rt, "tiny", 1, 5).expect("backend");
    let params = session.init_params(2).expect("init");
    let eta = 0.05f32;

    // Path A: fused artifact.
    let mut p_fused = params.clone();
    let loss_fused = session.step(&mut p_fused, eta, 0).expect("step");

    // Path B: grad artifact + host update (worker 0, same iteration 0).
    backend.on_iteration(0);
    let mut grad = vec![0.0f32; backend.params()];
    backend.partial_grad(0, &params, &mut grad);
    let loss_grad = backend.last_loss;
    let p_host: Vec<f32> = params
        .iter()
        .zip(&grad)
        .map(|(p, g)| p - eta * g)
        .collect();

    assert!(
        (loss_fused - loss_grad).abs() < 1e-4,
        "losses diverge: {loss_fused} vs {loss_grad}"
    );
    let max_rel = p_fused
        .iter()
        .zip(&p_host)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    assert!(max_rel < 1e-4, "params diverge by {max_rel}");
}

#[test]
fn fastest_k_transformer_training_descends() {
    let rt = runtime();
    let session = TransformerSession::new(&rt, "tiny", 11).expect("session");
    let workers = 4;
    let mut backend =
        TransformerBackend::new(&rt, "tiny", workers, 11).expect("backend");
    let eval = TransformerBackend::new(&rt, "tiny", workers, 11).expect("eval");
    let params0 = session.init_params(3).expect("init");
    let delays = ExponentialDelays::new(1.0);
    let mut policy = FixedK::new(2);
    let cfg = MasterConfig {
        eta: 0.05,
        momentum: 0.0,
        max_iterations: 25,
        max_time: 0.0,
        seed: 4,
        record_stride: 5,
        intra_jobs: 1,
    };
    let run = run_fastest_k(
        &mut backend,
        &delays,
        &mut policy,
        &params0,
        &cfg,
        &mut |p| eval.eval_loss(p).unwrap() as f64,
    );
    let first = run.recorder.samples()[0].error;
    let last = run.recorder.last().unwrap().error;
    assert!(
        last < first - 0.15,
        "fastest-k transformer failed to learn: {first} -> {last}"
    );
}
