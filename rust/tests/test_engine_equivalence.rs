//! Driver ↔ engine equivalence: the PR-3 refactor moved all three
//! training drivers onto `engine::RoundEngine`. This file keeps the
//! *pre-refactor* sync and async run loops alive as executable
//! specifications (transplanted verbatim below, minus the result-struct
//! plumbing) and asserts the engine-backed drivers reproduce them —
//! bit for bit on the default dense channel, and sample-for-sample
//! (still exact: the engine performs the identical operations in the
//! identical order) under non-trivial comm configurations.

use adasgd::async_sgd::{run_async_comm, AsyncConfig};
use adasgd::comm::{
    Broadcast, CommChannel, Dense, DownlinkMode, IngressModel, LinkModel,
    QuantizeQsgd, TopK,
};
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::grad::{GradBackend, NativeBackend};
use adasgd::master::{fastest_k_select, run_fastest_k_comm, MasterConfig};
use adasgd::metrics::{Recorder, Sample};
use adasgd::model::LinRegProblem;
use adasgd::policy::{
    AdaptivePflug, FixedK, IterationObs, KPolicy, PflugParams,
};
use adasgd::rng::Pcg64;
use adasgd::sim::EventQueue;
use adasgd::straggler::DelayModel;

/// What both the reference loops and the engine shims are compared on.
struct RefRun {
    w: Vec<f32>,
    total_time: f64,
    steps: u64,
    samples: Vec<Sample>,
    k_changes: Vec<(u64, f64, usize)>,
}

/// The pre-engine synchronous fastest-k loop, verbatim.
fn reference_fastest_k(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    policy: &mut dyn KPolicy,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &MasterConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> RefRun {
    let n = backend.n_shards();
    let d = backend.dim();
    let mut rng = Pcg64::seed_stream(cfg.seed, 0xFA57);
    let mut comm_rng = Pcg64::seed_stream(cfg.seed, 0xC044);
    let mut bcast_rng = Pcg64::seed_stream(cfg.seed, 0xB04D);
    let bytes0 = channel.stats.bytes_sent;
    let comm_t0 = channel.stats.comm_time;
    let down0 = channel.stats.bytes_down;
    let down_t0 = channel.stats.down_time;
    let mut w = w0.to_vec();
    let mut w_view = w0.to_vec();
    let mut g = vec![0.0f32; d];
    let mut g_prev = vec![0.0f32; d];
    let mut partial = vec![0.0f32; d];
    let mut decoded = vec![0.0f32; d];
    let mut velocity: Option<Vec<f32>> = None;
    let mut all_buf: Option<Vec<f32>> = None;
    let mut delay_buf = vec![0.0f64; n];
    let mut idx_buf: Vec<usize> = Vec::with_capacity(n);
    let mut arrival_buf: Vec<f64> = Vec::with_capacity(n);
    let ingress = *channel.ingress();

    let mut recorder =
        Recorder::with_stride(policy.name(), cfg.record_stride);
    let mut k_changes = Vec::new();
    let mut k = policy.initial_k().min(n).max(1);
    let mut t = 0.0f64;
    let mut j = 0u64;
    let msg_bytes = channel.message_bytes(d);

    recorder.push_forced(Sample {
        iteration: 0,
        time: 0.0,
        k,
        error: eval_error(&w),
        ..Default::default()
    });

    while j < cfg.max_iterations && (cfg.max_time <= 0.0 || t < cfg.max_time)
    {
        backend.on_iteration(j);
        let down_bytes =
            channel.broadcast_model(&w, &mut w_view, &mut bcast_rng);
        for (i, slot) in delay_buf.iter_mut().enumerate() {
            *slot = delays.sample(j, i, &mut rng)
                + channel.link_upload_delay(i, msg_bytes)
                + channel.download_delay(i, down_bytes);
        }
        let (x_k, _) = fastest_k_select(&delay_buf, k, &mut idx_buf);
        let round_time = if ingress.is_unlimited() {
            x_k
        } else {
            arrival_buf.clear();
            arrival_buf.extend(idx_buf[..k].iter().map(|&i| delay_buf[i]));
            ingress.round_completion(&mut arrival_buf, msg_bytes)
        };
        t += round_time;

        g.iter_mut().for_each(|v| *v = 0.0);
        let use_batched = backend.supports_all_grads() && 4 * k >= n;
        let mut batched = false;
        if use_batched {
            let buf = all_buf.get_or_insert_with(|| vec![0.0f32; n * d]);
            batched = backend.all_grads(&w_view, buf);
        }
        if batched {
            let buf =
                all_buf.as_ref().expect("batched scratch allocated above");
            for &worker in &idx_buf[..k] {
                let row = &buf[worker * d..(worker + 1) * d];
                channel.transmit(worker, row, &mut decoded, &mut comm_rng);
                for (gv, pv) in g.iter_mut().zip(&decoded) {
                    *gv += *pv;
                }
            }
        } else {
            for &worker in &idx_buf[..k] {
                backend.partial_grad(worker, &w_view, &mut partial);
                channel.transmit(
                    worker,
                    &partial,
                    &mut decoded,
                    &mut comm_rng,
                );
                for (gv, pv) in g.iter_mut().zip(&decoded) {
                    *gv += *pv;
                }
            }
        }
        let inv_k = 1.0 / k as f32;
        for gv in g.iter_mut() {
            *gv *= inv_k;
        }

        if cfg.momentum > 0.0 {
            let v = velocity.get_or_insert_with(|| vec![0.0f32; d]);
            for ((vv, wv), gv) in v.iter_mut().zip(w.iter_mut()).zip(&g) {
                *vv = cfg.momentum * *vv + *gv;
                *wv -= cfg.eta * *vv;
            }
        } else {
            for (wv, gv) in w.iter_mut().zip(&g) {
                *wv -= cfg.eta * *gv;
            }
        }

        let inner = if j == 0 {
            None
        } else {
            Some(adasgd::linalg::dot(&g, &g_prev))
        };
        let obs = IterationObs {
            iteration: j,
            time: t,
            k_used: k,
            grad_inner_prev: inner,
            grad_norm_sq: adasgd::linalg::dot(&g, &g),
        };
        let k_next = policy.next_k(&obs).min(n).max(1);
        if k_next != k {
            k_changes.push((j, t, k_next));
            k = k_next;
        }
        std::mem::swap(&mut g, &mut g_prev);

        j += 1;
        if j % cfg.record_stride == 0 {
            recorder.push_forced(Sample {
                iteration: j,
                time: t,
                k,
                error: eval_error(&w),
                bytes: channel.stats.bytes_sent - bytes0,
                comm_time: channel.stats.comm_time - comm_t0,
                bytes_down: channel.stats.bytes_down - down0,
                down_time: channel.stats.down_time - down_t0,
            });
        }
    }

    if j % cfg.record_stride != 0 {
        recorder.push_forced(Sample {
            iteration: j,
            time: t,
            k,
            error: eval_error(&w),
            bytes: channel.stats.bytes_sent - bytes0,
            comm_time: channel.stats.comm_time - comm_t0,
            bytes_down: channel.stats.bytes_down - down0,
            down_time: channel.stats.down_time - down_t0,
        });
    }

    RefRun {
        w,
        total_time: t,
        steps: j,
        samples: recorder.samples().to_vec(),
        k_changes,
    }
}

/// The pre-engine asynchronous loop, verbatim (FIFO ingress chain).
fn reference_async(
    backend: &mut dyn GradBackend,
    delays: &dyn DelayModel,
    channel: &mut CommChannel,
    w0: &[f32],
    cfg: &AsyncConfig,
    eval_error: &mut dyn FnMut(&[f32]) -> f64,
) -> RefRun {
    let n = backend.n_shards();
    let d = backend.dim();
    let mut rng = Pcg64::seed_stream(cfg.seed, 0xA57C);
    let mut comm_rng = Pcg64::seed_stream(cfg.seed, 0xC045);
    let mut bcast_rng = Pcg64::seed_stream(cfg.seed, 0xB04E);
    let bytes0 = channel.stats.bytes_sent;
    let comm_t0 = channel.stats.comm_time;
    let down0 = channel.stats.bytes_down;
    let down_t0 = channel.stats.down_time;
    let mut w = w0.to_vec();
    let mut g_raw = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    let ingress = *channel.ingress();
    let mut ingress_free = f64::NEG_INFINITY;
    let mut clock = 0.0f64;
    let msg_bytes = channel.message_bytes(d);

    let mut snapshots: Vec<Vec<f32>> = vec![w.clone(); n];
    let mut read_version = vec![0u64; n];
    let mut version = 0u64;

    let mut queue: EventQueue<usize> = EventQueue::new();
    for i in 0..n {
        let dt = delays.sample(0, i, &mut rng)
            + channel.link_upload_delay(i, msg_bytes);
        queue.schedule_in(dt, i);
    }

    let mut recorder = Recorder::with_stride("async", cfg.record_stride);
    recorder.push_forced(Sample {
        iteration: 0,
        time: 0.0,
        k: 1,
        error: eval_error(&w),
        ..Default::default()
    });

    let mut updates = 0u64;
    while updates < cfg.max_updates {
        let ev = match queue.pop() {
            Some(e) => e,
            None => break,
        };
        let t_apply = ingress.serve_at(ev.time, ingress_free, msg_bytes);
        ingress_free = t_apply;
        clock = t_apply;
        if cfg.max_time > 0.0 && t_apply > cfg.max_time {
            break;
        }
        let i = ev.payload;

        backend.partial_grad(i, &snapshots[i], &mut g_raw);
        channel.transmit(i, &g_raw, &mut g, &mut comm_rng);
        let staleness = version - read_version[i];
        let step = if cfg.staleness_damping {
            cfg.eta / (1.0 + staleness as f32)
        } else {
            cfg.eta
        };
        for (wv, gv) in w.iter_mut().zip(&g) {
            *wv -= step * *gv;
        }
        version += 1;
        updates += 1;
        if !w[0].is_finite() {
            recorder.push_forced(Sample {
                iteration: updates,
                time: clock,
                k: 1,
                error: f64::INFINITY,
                bytes: channel.stats.bytes_sent - bytes0,
                comm_time: channel.stats.comm_time - comm_t0,
                bytes_down: channel.stats.bytes_down - down0,
                down_time: channel.stats.down_time - down_t0,
            });
            break;
        }

        let replay = match channel.downlink_mode() {
            DownlinkMode::Full => 1,
            DownlinkMode::Delta => staleness + 1,
        };
        let (_, down_delay) = channel.push_model(
            i,
            &w,
            &mut snapshots[i],
            replay,
            &mut bcast_rng,
        );
        read_version[i] = version;
        let dt = delays.sample(updates, i, &mut rng)
            + channel.link_upload_delay(i, msg_bytes)
            + down_delay;
        queue.schedule_at(t_apply + dt, i);

        if updates % cfg.record_stride == 0 {
            recorder.push_forced(Sample {
                iteration: updates,
                time: clock,
                k: 1,
                error: eval_error(&w),
                bytes: channel.stats.bytes_sent - bytes0,
                comm_time: channel.stats.comm_time - comm_t0,
                bytes_down: channel.stats.bytes_down - down0,
                down_time: channel.stats.down_time - down_t0,
            });
        }
    }

    let total_time = clock;
    if w[0].is_finite() && updates % cfg.record_stride != 0 {
        recorder.push_forced(Sample {
            iteration: updates,
            time: total_time,
            k: 1,
            error: eval_error(&w),
            bytes: channel.stats.bytes_sent - bytes0,
            comm_time: channel.stats.comm_time - comm_t0,
            bytes_down: channel.stats.bytes_down - down0,
            down_time: channel.stats.down_time - down_t0,
        });
    }

    RefRun {
        w,
        total_time,
        steps: updates,
        samples: recorder.samples().to_vec(),
        k_changes: Vec::new(),
    }
}

// ---------------------------------------------------------------------
// Fixtures.
// ---------------------------------------------------------------------

fn setup(seed: u64) -> (NativeBackend, LinRegProblem) {
    let ds = SyntheticDataset::generate(
        SyntheticConfig { m: 200, d: 10, ..Default::default() },
        seed,
    );
    let problem = LinRegProblem::new(&ds);
    (NativeBackend::new(Shards::partition(&ds, 10)), problem)
}

type ChannelFactory = Box<dyn Fn() -> CommChannel>;

/// The channel configurations both drivers are compared under. Index 0
/// is the default dense channel (the bit-for-bit contract); the rest
/// exercise compression, error feedback, finite links, delta downlink,
/// and finite FIFO ingress together.
fn channels() -> Vec<(&'static str, ChannelFactory)> {
    vec![
        ("dense-default", Box::new(|| CommChannel::dense(10))),
        (
            "topk-ef-uplink",
            Box::new(|| {
                CommChannel::new(
                    Box::new(TopK::new(0.3)),
                    LinkModel::uniform(10, 400.0, 0.01),
                    true,
                )
            }),
        ),
        (
            "qsgd-delta-ingress",
            Box::new(|| {
                CommChannel::new(
                    Box::new(QuantizeQsgd::new(4)),
                    LinkModel::uniform(10, 800.0, 0.0),
                    true,
                )
                .with_broadcast(Broadcast::new(
                    Box::new(TopK::new(0.5)),
                    LinkModel::uniform(10, 400.0, 0.0),
                    DownlinkMode::Delta,
                ))
                .with_ingress(IngressModel::new(500.0))
            }),
        ),
        (
            "dense-hetero-downlink",
            Box::new(|| {
                CommChannel::new(
                    Box::new(Dense::new()),
                    LinkModel::zero_cost(10),
                    false,
                )
                .with_broadcast(Broadcast::new(
                    Box::new(Dense::new()),
                    LinkModel::per_worker(
                        (0..10).map(|i| 100.0 * (i + 1) as f64).collect(),
                        vec![0.0; 10],
                    ),
                    DownlinkMode::Full,
                ))
            }),
        ),
    ]
}

fn assert_runs_equal(tag: &str, reference: &RefRun, engine: &RefRun) {
    assert_eq!(reference.steps, engine.steps, "{tag}: steps");
    assert_eq!(
        reference.w, engine.w,
        "{tag}: final model must be bitwise identical"
    );
    assert_eq!(
        reference.total_time.to_bits(),
        engine.total_time.to_bits(),
        "{tag}: clock must be bitwise identical ({} vs {})",
        reference.total_time,
        engine.total_time
    );
    assert_eq!(
        reference.k_changes, engine.k_changes,
        "{tag}: k-switch log"
    );
    assert_eq!(
        reference.samples.len(),
        engine.samples.len(),
        "{tag}: sample count"
    );
    for (a, b) in reference.samples.iter().zip(&engine.samples) {
        assert_eq!(a, b, "{tag}: recorded sample mismatch");
    }
}

// ---------------------------------------------------------------------
// Sync equivalence.
// ---------------------------------------------------------------------

#[test]
fn engine_reproduces_the_pre_refactor_sync_driver() {
    for seed in [0u64, 1, 7, 23] {
        for (name, make_channel) in channels() {
            let cfg = MasterConfig {
                eta: 0.002,
                max_iterations: 150,
                seed,
                record_stride: 20,
                ..Default::default()
            };
            let w0 = vec![0.0f32; 10];
            let reference = {
                let (mut backend, problem) = setup(seed);
                let mut policy = FixedK::new(4);
                let mut channel = make_channel();
                reference_fastest_k(
                    &mut backend,
                    &delays(),
                    &mut policy,
                    &mut channel,
                    &w0,
                    &cfg,
                    &mut |w| problem.error(w),
                )
            };
            let engine = {
                let (mut backend, problem) = setup(seed);
                let mut policy = FixedK::new(4);
                let mut channel = make_channel();
                let run = run_fastest_k_comm(
                    &mut backend,
                    &delays(),
                    &mut policy,
                    &mut channel,
                    &w0,
                    &cfg,
                    &mut |w| problem.error(w),
                );
                RefRun {
                    w: run.w,
                    total_time: run.total_time,
                    steps: run.iterations,
                    samples: run.recorder.samples().to_vec(),
                    k_changes: run.k_changes,
                }
            };
            assert_runs_equal(
                &format!("sync/{name}/seed{seed}"),
                &reference,
                &engine,
            );
        }
    }
}

#[test]
fn engine_reproduces_the_adaptive_sync_driver_with_time_budget() {
    // The adaptive policy exercises the k-change path; the time budget
    // exercises the stop condition.
    for seed in [3u64, 11] {
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: u64::MAX / 2,
            max_time: 40.0,
            seed,
            record_stride: 10,
            ..Default::default()
        };
        let params = PflugParams {
            k0: 2,
            step: 3,
            thresh: 5,
            burnin: 10,
            k_max: 10,
        };
        let w0 = vec![0.0f32; 10];
        let reference = {
            let (mut backend, problem) = setup(seed);
            let mut policy = AdaptivePflug::new(10, params);
            let mut channel = CommChannel::dense(10);
            reference_fastest_k(
                &mut backend,
                &delays(),
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            )
        };
        let engine = {
            let (mut backend, problem) = setup(seed);
            let mut policy = AdaptivePflug::new(10, params);
            let mut channel = CommChannel::dense(10);
            let run = run_fastest_k_comm(
                &mut backend,
                &delays(),
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
            );
            RefRun {
                w: run.w,
                total_time: run.total_time,
                steps: run.iterations,
                samples: run.recorder.samples().to_vec(),
                k_changes: run.k_changes,
            }
        };
        assert_runs_equal(
            &format!("sync/adaptive/seed{seed}"),
            &reference,
            &engine,
        );
        assert!(
            !reference.k_changes.is_empty(),
            "fixture must exercise k switches to be meaningful"
        );
    }
}

// ---------------------------------------------------------------------
// Async equivalence.
// ---------------------------------------------------------------------

#[test]
fn engine_reproduces_the_pre_refactor_async_driver() {
    for seed in [0u64, 5, 13] {
        for (name, make_channel) in channels() {
            let cfg = AsyncConfig {
                eta: 0.0005,
                max_updates: 800,
                seed,
                record_stride: 100,
                ..Default::default()
            };
            let w0 = vec![0.0f32; 10];
            let reference = {
                let (mut backend, problem) = setup(seed);
                let mut channel = make_channel();
                reference_async(
                    &mut backend,
                    &delays(),
                    &mut channel,
                    &w0,
                    &cfg,
                    &mut |w| problem.error(w),
                )
            };
            let engine = {
                let (mut backend, problem) = setup(seed);
                let mut channel = make_channel();
                let run = run_async_comm(
                    &mut backend,
                    &delays(),
                    &mut channel,
                    &w0,
                    &cfg,
                    &mut |w| problem.error(w),
                );
                RefRun {
                    w: run.w,
                    total_time: run.total_time,
                    steps: run.updates,
                    samples: run.recorder.samples().to_vec(),
                    k_changes: Vec::new(),
                }
            };
            assert_runs_equal(
                &format!("async/{name}/seed{seed}"),
                &reference,
                &engine,
            );
        }
    }
}

#[test]
fn engine_reproduces_the_async_driver_under_a_time_budget() {
    let cfg = AsyncConfig {
        eta: 0.0002,
        max_updates: u64::MAX / 2,
        max_time: 25.0,
        seed: 9,
        record_stride: 50,
        ..Default::default()
    };
    let w0 = vec![0.0f32; 10];
    let reference = {
        let (mut backend, problem) = setup(9);
        let mut channel = CommChannel::dense(10);
        reference_async(
            &mut backend,
            &delays(),
            &mut channel,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        )
    };
    let engine = {
        let (mut backend, problem) = setup(9);
        let mut channel = CommChannel::dense(10);
        let run = run_async_comm(
            &mut backend,
            &delays(),
            &mut channel,
            &w0,
            &cfg,
            &mut |w| problem.error(w),
        );
        RefRun {
            w: run.w,
            total_time: run.total_time,
            steps: run.updates,
            samples: run.recorder.samples().to_vec(),
            k_changes: Vec::new(),
        }
    };
    assert_runs_equal("async/time-budget", &reference, &engine);
}

fn delays() -> adasgd::straggler::ExponentialDelays {
    adasgd::straggler::ExponentialDelays::new(1.0)
}

// ---------------------------------------------------------------------
// Threaded ↔ simulated determinism: the live cluster decides by virtual
// time, so real thread scheduling cannot change a trajectory.
// ---------------------------------------------------------------------

#[test]
fn threaded_fastest_k_with_an_adaptive_policy_reproduces_the_simulator() {
    use adasgd::exec::{ThreadedCluster, ThreadedConfig};
    // TopK + error feedback over a finite uplink with finite FIFO
    // ingress: the compressor draws no rng, so the threaded per-worker
    // comm streams and the simulator's shared stream are both inert and
    // the two paths must agree bit for bit — including every adaptive
    // k switch, which depends on exact gradient inner products.
    // Same scale as the PR-3 adaptive equivalence fixture, which is
    // known to trigger Pflug switches early.
    let seed = 3u64;
    let ds = SyntheticDataset::generate(
        SyntheticConfig { m: 200, d: 10, ..Default::default() },
        seed,
    );
    let problem = LinRegProblem::new(&ds);
    let params = PflugParams {
        k0: 2,
        step: 3,
        thresh: 5,
        burnin: 10,
        k_max: 10,
    };
    let make_channel = || {
        CommChannel::new(
            Box::new(TopK::new(0.5)),
            LinkModel::uniform(10, 500.0, 0.01),
            true,
        )
        .with_ingress(IngressModel::new(300.0))
    };
    let sim = {
        let mut backend = NativeBackend::new(Shards::partition(&ds, 10));
        let mut policy = AdaptivePflug::new(10, params);
        let mut channel = make_channel();
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 600,
            seed,
            record_stride: 50,
            ..Default::default()
        };
        run_fastest_k_comm(
            &mut backend,
            &delays(),
            &mut policy,
            &mut channel,
            &vec![0.0f32; 10],
            &cfg,
            &mut |w| problem.error(w),
        )
    };
    let threaded = {
        let shards = Shards::partition(&ds, 10);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-6);
        let mut policy = AdaptivePflug::new(10, params);
        let mut channel = make_channel();
        let cfg = ThreadedConfig {
            eta: 0.002,
            max_iterations: 600,
            time_scale: 1e-6,
            seed,
            record_stride: 50,
            intra_jobs: 1,
        };
        cluster.run_with_comm(
            &delays(),
            &mut channel,
            &mut policy,
            &vec![0.0f32; 10],
            &cfg,
            &mut |w| problem.error(w),
        )
    };
    assert_eq!(sim.w, threaded.w, "final model");
    assert_eq!(
        sim.total_time.to_bits(),
        threaded.virtual_time.to_bits(),
        "virtual clock"
    );
    assert_eq!(sim.k_changes, threaded.k_changes, "adaptive k switches");
    assert!(
        !sim.k_changes.is_empty(),
        "fixture must exercise k switches to be meaningful"
    );
    assert_eq!(
        sim.recorder.samples(),
        threaded.recorder.samples(),
        "recorded series"
    );
    assert_eq!(sim.bytes_sent, threaded.bytes_sent);
}

#[test]
fn threaded_async_reproduces_the_simulated_async_path() {
    use adasgd::exec::ThreadedCluster;
    // The threaded async master applies responses in virtual completion
    // order with the simulator's rng streams; the worker threads run
    // the same gemv kernels as NativeBackend. Exact across channels —
    // even QSGD, whose shared comm stream draws in apply order on both
    // paths. (PS ingress is simulator-only and excluded here.)
    for seed in [2u64, 13] {
        for (name, make_channel) in channels() {
            let ds = SyntheticDataset::generate(
                SyntheticConfig { m: 200, d: 10, ..Default::default() },
                seed,
            );
            let problem = LinRegProblem::new(&ds);
            let cfg = AsyncConfig {
                eta: 0.0005,
                max_updates: 500,
                seed,
                record_stride: 100,
                ..Default::default()
            };
            let sim = {
                let mut backend =
                    NativeBackend::new(Shards::partition(&ds, 10));
                let mut channel = make_channel();
                run_async_comm(
                    &mut backend,
                    &delays(),
                    &mut channel,
                    &vec![0.0f32; 10],
                    &cfg,
                    &mut |w| problem.error(w),
                )
            };
            let threaded = {
                let shards = Shards::partition(&ds, 10);
                let mut cluster = ThreadedCluster::spawn(&shards, 1e-6);
                let mut channel = make_channel();
                cluster.run_async_comm(
                    &delays(),
                    &mut channel,
                    &vec![0.0f32; 10],
                    &cfg,
                    &mut |w| problem.error(w),
                )
            };
            let tag = format!("threaded-async/{name}/seed{seed}");
            assert_eq!(sim.w, threaded.w, "{tag}: final model");
            assert_eq!(
                sim.total_time.to_bits(),
                threaded.virtual_time.to_bits(),
                "{tag}: virtual clock"
            );
            assert_eq!(
                sim.recorder.samples(),
                threaded.recorder.samples(),
                "{tag}: recorded series"
            );
            assert_eq!(
                sim.mean_staleness, threaded.mean_staleness,
                "{tag}: staleness"
            );
            assert_eq!(sim.diverged, threaded.diverged, "{tag}");
        }
    }
}
