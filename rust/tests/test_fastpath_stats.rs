//! Statistical contract of the order-statistics fastpath.
//!
//! The fastpath (`[run] fastpath` / `--fastpath`) never draws the n
//! per-worker delays; it samples the first-k arrival times directly from
//! the order-statistics law in O(k). Its promise is **distributional**
//! equivalence with the exhaustive gather, not bitwise equality — so the
//! tests here are (a) fixed-seed Monte-Carlo agreement of moments and
//! quantiles between the two samplers on a small n where exhaustive is
//! cheap, (b) an exact pin of the sampler's closed-form `E[X_(k)]`
//! against the theory layer and the textbook harmonic-difference
//! formula, and (c) an end-to-end `run_experiment` pass showing the
//! fastpath trains, is seed-deterministic, and genuinely takes a
//! different (equally valid) trajectory than the exhaustive engine.

use adasgd::config::{
    CommSpec, CompressorSpec, DelaySpec, ExperimentConfig, PolicySpec,
    WorkloadSpec,
};
use adasgd::coordinator::run_experiment;
use adasgd::rng::{Pcg64, Rng};
use adasgd::stats::{
    quantile, ClassOrderSampler, OrderStatSampler, OrderStats,
};

const N: usize = 12;
const K: usize = 4;
const LAMBDA: f64 = 1.5;
/// Monte-Carlo rounds. At 60k the standard error of the k-th-arrival
/// mean is ~5e-4, so the 0.01 tolerances below sit at ~20 sigma: tight
/// enough to catch an off-by-one in the spacing rates (which shifts the
/// mean by ~0.02), loose enough to never flake on a fixed seed.
const ROUNDS: usize = 60_000;

fn mean_var(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var =
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var)
}

/// The exhaustive reference: draw all n delays, sort, take the k-th.
fn exhaustive_kth(rng: &mut Pcg64) -> f64 {
    let mut draws: Vec<f64> =
        (0..N).map(|_| -rng.next_f64_open().ln() / LAMBDA).collect();
    draws.sort_unstable_by(|a, b| a.total_cmp(b));
    draws[K - 1]
}

#[test]
fn fastpath_kth_arrival_matches_exhaustive_moments_and_quantiles() {
    let sampler = OrderStatSampler::exponential(N, LAMBDA);
    // Independent streams: the comparison is between two estimates of
    // the same distribution, not between coupled draws.
    let mut fast_rng = Pcg64::seed_stream(41, 1);
    let mut ex_rng = Pcg64::seed_stream(41, 2);
    let mut buf = Vec::new();
    let mut fast = Vec::with_capacity(ROUNDS);
    let mut exhaustive = Vec::with_capacity(ROUNDS);
    for _ in 0..ROUNDS {
        sampler.sample_first_k(K, &mut buf, &mut fast_rng);
        // Arrivals are nondecreasing by construction; the k-th is last.
        assert_eq!(buf.len(), K);
        assert!(buf.windows(2).all(|w| w[0] <= w[1]));
        fast.push(buf[K - 1]);
        exhaustive.push(exhaustive_kth(&mut ex_rng));
    }

    let (fm, fv) = mean_var(&fast);
    let (em, ev) = mean_var(&exhaustive);
    let theory = OrderStats::exponential(N, LAMBDA);
    assert!(
        (fm - theory.mean(K)).abs() < 0.01,
        "fastpath mean {fm} vs theory {}",
        theory.mean(K)
    );
    assert!(
        (em - theory.mean(K)).abs() < 0.01,
        "exhaustive mean {em} vs theory {}",
        theory.mean(K)
    );
    assert!((fm - em).abs() < 0.01, "means diverge: {fm} vs {em}");
    assert!(
        (fv - theory.var(K)).abs() < 0.004,
        "fastpath var {fv} vs theory {}",
        theory.var(K)
    );
    assert!((fv - ev).abs() < 0.004, "variances diverge: {fv} vs {ev}");
    for q in [0.1, 0.25, 0.5, 0.75, 0.9] {
        let qf = quantile(&fast, q);
        let qe = quantile(&exhaustive, q);
        assert!(
            (qf - qe).abs() < 0.02,
            "q={q}: fastpath {qf} vs exhaustive {qe}"
        );
    }
}

#[test]
fn expected_kth_pins_to_theory_and_the_harmonic_closed_form() {
    for (n, k, lambda) in [(10, 3, 1.0), (50, 49, 2.0), (1000, 1, 0.5)] {
        let got = OrderStatSampler::exponential(n, lambda)
            .expected_kth(k)
            .expect("exponential has a closed-form order mean");
        let theory = OrderStats::exponential(n, lambda).mean(k);
        assert!(
            (got - theory).abs() <= 1e-12 * theory.abs().max(1.0),
            "n={n} k={k}: sampler {got} vs theory {theory}"
        );
        // E[X_(k)] = (H_n - H_{n-k}) / lambda, summed independently.
        let hn: f64 = (1..=n).map(|i| 1.0 / i as f64).sum();
        let hnk: f64 = (1..=(n - k)).map(|i| 1.0 / i as f64).sum();
        let closed = (hn - hnk) / lambda;
        assert!(
            (got - closed).abs() < 1e-9,
            "n={n} k={k}: sampler {got} vs closed form {closed}"
        );
    }
    // Heavy-tailed models have no harmonic closed form wired in; the
    // sampler must say so rather than guess.
    assert!(OrderStatSampler::pareto(10, 0.5, 2.5).expected_kth(3).is_none());
    assert!(OrderStatSampler::weibull(10, 1.0, 1.5).expected_kth(3).is_none());
}

fn fast_cfg() -> ExperimentConfig {
    ExperimentConfig {
        label: "fastpath-e2e".into(),
        n: 10,
        eta: 2e-3,
        max_iterations: 400,
        max_time: 0.0,
        seed: 11,
        record_stride: 50,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 5 },
        workload: WorkloadSpec::LinReg { m: 200, d: 10 },
        comm: Default::default(),
        coding: None,
        jobs: 0,
        intra_jobs: 1,
        trace: None,
        fastpath: true,
    }
}

#[test]
fn fastpath_experiment_trains_and_is_seed_deterministic() {
    let out1 = run_experiment(&fast_cfg()).expect("fastpath run");
    assert_eq!(out1.steps, 400);
    assert!(out1.total_time > 0.0);
    let first = out1.recorder.samples()[0].error;
    let last = out1.recorder.last().unwrap().error;
    assert!(last < first * 1e-2, "no training progress: {first} -> {last}");

    // Same seed, same trajectory: the fastpath is fully deterministic
    // even though it is only distributionally tied to the exhaustive
    // gather.
    let out2 = run_experiment(&fast_cfg()).expect("fastpath rerun");
    assert_eq!(out1.recorder.samples(), out2.recorder.samples());
    assert_eq!(out1.total_time.to_bits(), out2.total_time.to_bits());
    assert_eq!(out1.k_changes, out2.k_changes);

    // And it is a *different* draw than the exhaustive engine on the
    // same config — the contract is the law, not the bits.
    let mut ex_cfg = fast_cfg();
    ex_cfg.fastpath = false;
    let ex = run_experiment(&ex_cfg).expect("exhaustive run");
    assert_eq!(ex.steps, 400);
    assert_ne!(out1.total_time.to_bits(), ex.total_time.to_bits());
    // Both drivers reach the same error regime on this workload.
    let ex_last = ex.recorder.last().unwrap().error;
    assert!(
        last < ex_last * 50.0 && ex_last < last * 50.0,
        "trajectories should land in the same regime: {last} vs {ex_last}"
    );
}

/// A class-heterogeneous priced configuration: a persistently slow
/// delay group (workers 0–2, bimodal with p_transient = 0), a slowed
/// uplink tail (workers 7–9 via comm.slow_workers), a priced uniform
/// downlink, and optionally a lossy uplink scheme and a finite FIFO
/// ingress. Up to three (delay class × uplink constant) classes.
fn het_cfg(
    topk: bool,
    finite_ingress: bool,
    fastpath: bool,
    seed: u64,
    iters: u64,
) -> ExperimentConfig {
    let mut cfg = fast_cfg();
    cfg.label = "fastpath-het".into();
    cfg.seed = seed;
    cfg.max_iterations = iters;
    cfg.delays = DelaySpec::Bimodal {
        lambda: 1.0,
        n_slow: 3,
        slow_factor: 5.0,
        p_transient: 0.0,
    };
    cfg.comm = CommSpec {
        scheme: if topk {
            CompressorSpec::TopK { frac: 0.4 }
        } else {
            CompressorSpec::Dense
        },
        error_feedback: false,
        bandwidth: 2000.0,
        latency: 0.02,
        slow_workers: 3,
        slow_factor: 4.0,
        down_bandwidth: 500.0,
        ingress_bw: if finite_ingress { 1500.0 } else { 0.0 },
        ..Default::default()
    };
    cfg.fastpath = fastpath;
    cfg
}

#[test]
fn heterogeneous_priced_fastpath_matches_exhaustive_mean_round_times() {
    // Both engines price a round as: per-worker compute delay + uplink
    // constant + uniform download, fastest-k selection on that sum,
    // then the FIFO ingress chain when finite. The fastpath draws the
    // merged prefix directly; over many rounds the mean round time of
    // the two paths must agree for every scheme × ingress combination.
    for (topk, finite_ingress) in
        [(false, false), (false, true), (true, false), (true, true)]
    {
        let rounds = 4_000u64;
        let fast =
            run_experiment(&het_cfg(topk, finite_ingress, true, 23, rounds))
                .expect("heterogeneous fastpath run");
        let ex =
            run_experiment(&het_cfg(topk, finite_ingress, false, 29, rounds))
                .expect("heterogeneous exhaustive run");
        assert_eq!(fast.steps, rounds);
        assert_eq!(ex.steps, rounds);
        let fm = fast.total_time / fast.steps as f64;
        let em = ex.total_time / ex.steps as f64;
        assert!(
            (fm - em).abs() < 0.05,
            "topk={topk} ingress={finite_ingress}: per-round fastpath \
             {fm} vs exhaustive {em}"
        );
        // Identical pricing rules: byte meters agree exactly (both
        // accept k messages of the same data-independent size each
        // round) and both paths train.
        assert_eq!(fast.bytes_sent, ex.bytes_sent);
        assert!(fast.comm_time > 0.0);
        assert!(fast.down_time > 0.0);
        let f_last = fast.recorder.last().unwrap().error;
        let f_first = fast.recorder.samples()[0].error;
        assert!(f_last < f_first * 1e-2, "{f_first} -> {f_last}");
    }
    // The finite-FIFO variant is strictly slower than the
    // independent-upload model of the same config, on both paths.
    let rounds = 1_500u64;
    let free = run_experiment(&het_cfg(true, false, true, 31, rounds))
        .expect("unlimited-ingress fastpath");
    let cong = run_experiment(&het_cfg(true, true, true, 31, rounds))
        .expect("finite-ingress fastpath");
    assert!(cong.total_time > free.total_time);
}

#[test]
fn heterogeneous_priced_fastpath_matches_exhaustive_quantiles() {
    // Distributional agreement beyond the mean: the first-round
    // completion time across independent seeds, fastpath vs exhaustive,
    // on the fully priced combination (TopK uplink + finite FIFO
    // ingress + slow classes).
    let seeds = 400u64;
    let mut fast = Vec::with_capacity(seeds as usize);
    let mut ex = Vec::with_capacity(seeds as usize);
    for s in 0..seeds {
        fast.push(
            run_experiment(&het_cfg(true, true, true, 1000 + s, 1))
                .expect("fastpath round")
                .total_time,
        );
        ex.push(
            run_experiment(&het_cfg(true, true, false, 5000 + s, 1))
                .expect("exhaustive round")
                .total_time,
        );
    }
    for q in [0.25, 0.5, 0.75] {
        let qf = quantile(&fast, q);
        let qe = quantile(&ex, q);
        assert!(
            (qf - qe).abs() < 0.12,
            "q={q}: fastpath {qf} vs exhaustive {qe}"
        );
    }
}

#[test]
fn class_shift_translates_arrivals_exactly() {
    // A per-class constant uplink shift must translate every merged
    // arrival by exactly that constant — bitwise, not approximately —
    // because the shift is added once per draw, after sampling.
    let base = OrderStatSampler::exponential(40, 1.3);
    let shift = 0.75f64;
    let mut plain = ClassOrderSampler::new(vec![(base.clone(), 0.0)]);
    let mut shifted = ClassOrderSampler::new(vec![(base, shift)]);
    let (mut a0, mut c0) = (Vec::new(), Vec::new());
    let (mut a1, mut c1) = (Vec::new(), Vec::new());
    let mut rng0 = Pcg64::seed(97);
    let mut rng1 = Pcg64::seed(97);
    for k in [1usize, 5, 17] {
        plain.sample_first_k(k, &mut a0, &mut c0, &mut rng0);
        shifted.sample_first_k(k, &mut a1, &mut c1, &mut rng1);
        assert_eq!(c0, c1);
        for (p, s) in a0.iter().zip(&a1) {
            assert_eq!(
                (p + shift).to_bits(),
                s.to_bits(),
                "k={k}: {p} + {shift} vs {s}"
            );
        }
    }
}

#[test]
fn single_class_merge_reproduces_the_iid_sampler_draw_for_draw() {
    // With one class the k-way merge must consume the rng identically
    // to the plain i.i.d. sampler — this is what keeps every default
    // (free-comm, i.i.d.) fastpath trajectory byte-identical across
    // the generalization.
    let iid = OrderStatSampler::weibull(25, 1.1, 0.8);
    let mut merged = ClassOrderSampler::single(iid.clone());
    let mut batch = Vec::new();
    let (mut arrivals, mut classes) = (Vec::new(), Vec::new());
    let mut rng_a = Pcg64::seed(12345);
    let mut rng_b = Pcg64::seed(12345);
    for k in [1usize, 8, 25] {
        iid.sample_first_k(k, &mut batch, &mut rng_a);
        merged.sample_first_k(k, &mut arrivals, &mut classes, &mut rng_b);
        assert_eq!(batch.len(), arrivals.len());
        for (b, m) in batch.iter().zip(&arrivals) {
            assert_eq!(b.to_bits(), m.to_bits(), "k={k}");
        }
        assert!(classes.iter().all(|&c| c == 0));
        // The rngs stay aligned after each round.
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }
}

#[test]
fn fastpath_round_times_average_the_order_statistic() {
    // The engine's clock advances by the sampled k-th arrival each
    // round, so total_time / steps is a Monte-Carlo estimate of
    // E[X_(k)] — tie the end-to-end run back to the theory layer.
    let mut cfg = fast_cfg();
    cfg.max_iterations = 2_000;
    let out = run_experiment(&cfg).expect("fastpath run");
    let per_round = out.total_time / out.steps as f64;
    let want = OrderStats::exponential(10, 1.0).mean(5);
    // sigma(X_(5)) ~ 0.3 for n=10 => SE over 2000 rounds ~ 0.007.
    assert!(
        (per_round - want).abs() < 0.05,
        "per-round time {per_round} vs E[X_(5)] = {want}"
    );
}
