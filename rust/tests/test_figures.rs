//! Shape assertions for the paper's figures: who wins, by roughly what
//! factor, where the crossovers fall. Absolute error values are data- and
//! seed-dependent; the *orderings* below are what the paper claims.

use adasgd::coordinator::{fig1, fig2, fig3};
use adasgd::stats::OrderStats;
use adasgd::theory::{adaptive_envelope, switching_times, BoundParams, ErrorBound};

#[test]
fn fig1_adaptive_traces_the_lower_envelope() {
    let bound = ErrorBound::new(
        BoundParams::example1(),
        OrderStats::exponential(5, 5.0),
    );
    let ts: Vec<f64> = (0..500).map(|i| i as f64 * 25.0).collect();
    let env = adaptive_envelope(&bound, &ts);
    // At every instant the envelope is within a hair of the best fixed k —
    // and at late times strictly below every k < 5 floor.
    for (i, &t) in ts.iter().enumerate() {
        let best = (1..=5).map(|k| bound.eval(k, t)).fold(f64::INFINITY, f64::min);
        assert!(
            env[i] <= best + 1e-12,
            "t={t}: envelope {} above best fixed {}",
            env[i],
            best
        );
    }
    let t_end = *ts.last().unwrap();
    for k in 1..5 {
        assert!(env.last().unwrap() < &bound.eval(k, t_end));
    }
}

#[test]
fn fig1_switching_times_are_ordered_and_finite() {
    let bound = ErrorBound::new(
        BoundParams::example1(),
        OrderStats::exponential(5, 5.0),
    );
    let sw = switching_times(&bound);
    assert_eq!(sw.len(), 4);
    for w in sw.windows(2) {
        assert!(w[0].time < w[1].time);
        assert!(w[0].error > w[1].error);
    }
    assert!(sw[0].time > 100.0 && sw[3].time < 1e5, "{sw:?}");
}

#[test]
fn fig1_output_is_complete() {
    let out = fig1(100);
    assert_eq!(out.fixed.len(), 5);
    assert_eq!(out.adaptive.samples().len(), 100);
    assert!(!out.summary.is_empty());
}

/// Fig. 2's claims: (i) fixed-k floors are ordered floor(10) > floor(40);
/// (ii) the adaptive run reaches the k=40 error level well before the
/// fixed k=40 run; (iii) adaptive's minimum error is the lowest of all.
#[test]
fn fig2_adaptive_beats_fixed() {
    let out = fig2(0, 6500.0);
    let by_label = |needle: &str| {
        out.runs
            .iter()
            .find(|r| r.label.contains(needle))
            .unwrap_or_else(|| panic!("missing run {needle}"))
    };
    let k10 = by_label("k=10");
    let k40 = by_label("k=40");
    let adaptive = by_label("adaptive");

    // (i) floor ordering: error at the end of the window.
    let e10 = k10.last().unwrap().error;
    let e40 = k40.last().unwrap().error;
    assert!(
        e10 > 2.0 * e40,
        "k=10 floor ({e10:.3e}) should sit well above k=40 ({e40:.3e})"
    );

    // (ii) time-to-error: target = the k=40 terminal error level.
    let target = e40 * 1.5;
    let t_adaptive = adaptive
        .time_to_error(target)
        .expect("adaptive must reach the k=40 level");
    let t_k40 = k40.time_to_error(target).expect("k=40 reaches its own level");
    assert!(
        t_adaptive < 0.75 * t_k40,
        "adaptive should be much earlier: {t_adaptive:.0} vs {t_k40:.0}"
    );
    // k=10 never gets there at all.
    assert!(k10.time_to_error(target).is_none());

    // (iii) adaptive min error is the global best (small tolerance).
    let adaptive_min = adaptive.min_error().unwrap();
    for r in &out.runs {
        assert!(
            adaptive_min <= r.min_error().unwrap() * 1.10,
            "adaptive {adaptive_min:.3e} vs {} {:.3e}",
            r.label,
            r.min_error().unwrap()
        );
    }
}

/// Fig. 3's claim: adaptive fastest-k reaches a lower error than fully
/// asynchronous SGD within the same time budget.
#[test]
fn fig3_adaptive_beats_async() {
    let out = fig3(0, 2500.0);
    let adaptive = out
        .runs
        .iter()
        .find(|r| r.label.contains("adaptive"))
        .expect("adaptive run");
    let async_run = out
        .runs
        .iter()
        .find(|r| r.label.contains("async"))
        .expect("async run");
    let a = adaptive.min_error().unwrap();
    let b = async_run.min_error().unwrap();
    assert!(
        a < 0.5 * b,
        "adaptive ({a:.3e}) should clearly beat async ({b:.3e})"
    );
}

/// Robustness: the Fig-2 ordering holds across seeds (not a lucky draw).
#[test]
fn fig2_ordering_is_seed_robust() {
    for seed in [1u64, 2] {
        let out = fig2(seed, 4000.0);
        let adaptive = out
            .runs
            .iter()
            .find(|r| r.label.contains("adaptive"))
            .unwrap();
        let k10 = out.runs.iter().find(|r| r.label.contains("k=10")).unwrap();
        assert!(
            adaptive.min_error().unwrap() < k10.min_error().unwrap(),
            "seed {seed}"
        );
    }
}
