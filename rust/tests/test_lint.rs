//! Self-coverage for `adasgd lint` (the detlint pass): every rule
//! fires on its known-bad fixture, stays quiet on the matching clean
//! fixture, and the whole repo lints clean — with every suppression
//! an explicit, still-visible pragma.
//!
//! Fixtures live in `rust/tests/lint_fixtures/` (never compiled, and
//! excluded from the repo walk so intentionally-bad files cannot
//! pollute the gate). Rule scoping is path-based, so each fixture is
//! linted under a virtual repo path chosen here.

use std::path::Path;

use adasgd::analysis::{
    lint_root, lint_sources, LintReport, CSV_SCHEMA_VERSIONS, RULES,
};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {}: {e}", path.display()))
}

/// Lint one fixture as if it lived at `rel` inside the repo.
fn lint_at(rel: &str, name: &str) -> LintReport {
    lint_sources(&[(rel.to_string(), fixture(name))])
}

fn rules_fired(report: &LintReport) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.rule).collect()
}

#[test]
fn d001_fires_on_bad_and_not_on_clean() {
    let bad = lint_at("rust/src/stats/fx.rs", "d001_bad.rs");
    assert_eq!(rules_fired(&bad), ["D001", "D001"]);
    // D001 applies everywhere, tests and benches included.
    let bad_test = lint_at("rust/tests/fx.rs", "d001_bad.rs");
    assert_eq!(bad_test.active_count(), 2);
    let clean = lint_at("rust/src/stats/fx.rs", "d001_clean.rs");
    assert_eq!(clean.active_count(), 0, "{:?}", clean.findings);
}

#[test]
fn d002_fires_in_det_modules_only() {
    for module in ["engine", "sweep", "trace", "sim", "comm", "coding"] {
        let rel = format!("rust/src/{module}/fx.rs");
        let bad = lint_sources(&[(rel, fixture("d002_bad.rs"))]);
        assert!(
            bad.active_count() >= 2,
            "{module}: {:?}",
            bad.findings
        );
        assert!(rules_fired(&bad).iter().all(|r| *r == "D002"));
    }
    // Same content outside the deterministic set is not D002's business.
    let other = lint_at("rust/src/metrics/fx.rs", "d002_bad.rs");
    assert_eq!(other.active_count(), 0);
    let clean = lint_at("rust/src/engine/fx.rs", "d002_clean.rs");
    assert_eq!(clean.active_count(), 0, "{:?}", clean.findings);
}

#[test]
fn d003_fires_suppresses_and_exempts() {
    let bad = lint_at("rust/src/exec/fx.rs", "d003_bad.rs");
    assert_eq!(rules_fired(&bad), ["D003", "D003"]);
    // bench_harness owns wall-clock measurement.
    let bench = lint_at("rust/src/bench_harness/fx.rs", "d003_bad.rs");
    assert_eq!(bench.active_count(), 0);
    // A pragma suppresses the gate but the finding stays visible.
    let allowed = lint_at("rust/src/exec/fx.rs", "d003_allowed.rs");
    assert_eq!(allowed.active_count(), 0);
    assert_eq!(allowed.suppressed_count(), 1);
    assert!(allowed.render_text().contains("suppressed by pragma"));
    let clean = lint_at("rust/src/exec/fx.rs", "d003_clean.rs");
    assert_eq!(clean.active_count(), 0, "{:?}", clean.findings);
}

#[test]
fn d004_fires_on_literal_seed_only() {
    let bad = lint_at("rust/src/straggler/fx.rs", "d004_bad.rs");
    assert_eq!(rules_fired(&bad), ["D004"]);
    let clean = lint_at("rust/src/straggler/fx.rs", "d004_clean.rs");
    assert_eq!(clean.active_count(), 0, "{:?}", clean.findings);
}

#[test]
fn d005_fires_in_library_not_cli() {
    let bad = lint_at("rust/src/policy/fx.rs", "d005_bad.rs");
    assert_eq!(rules_fired(&bad), ["D005"; 4]);
    for exempt in ["rust/src/cli/fx.rs", "rust/src/main.rs"] {
        let r = lint_sources(&[(
            exempt.to_string(),
            fixture("d005_bad.rs"),
        )]);
        assert_eq!(r.active_count(), 0, "{exempt}");
    }
    let clean = lint_at("rust/src/policy/fx.rs", "d005_clean.rs");
    assert_eq!(clean.active_count(), 0, "{:?}", clean.findings);
}

#[test]
fn d006_fires_outside_exec_only() {
    let bad = lint_at("rust/src/engine/fx.rs", "d006_bad.rs");
    assert_eq!(rules_fired(&bad), ["D006", "D006"]);
    // exec owns the pool; integration tests/benches have no top module
    // and may spawn scenario threads.
    for exempt in ["rust/src/exec/fx.rs", "rust/tests/fx.rs"] {
        let r = lint_sources(&[(
            exempt.to_string(),
            fixture("d006_bad.rs"),
        )]);
        assert_eq!(r.active_count(), 0, "{exempt}: {:?}", r.findings);
    }
    let clean = lint_at("rust/src/engine/fx.rs", "d006_clean.rs");
    assert_eq!(clean.active_count(), 0, "{:?}", clean.findings);
}

#[test]
fn l001_fires_on_layering_violations() {
    let bad = lint_at("rust/src/engine/fx.rs", "l001_bad.rs");
    assert_eq!(rules_fired(&bad), ["L001", "L001"]);
    assert!(bad.findings[0].message.contains("crate::sweep"));
    assert!(bad.findings[1].message.contains("crate::cli"));
    let clean = lint_at("rust/src/engine/fx.rs", "l001_clean.rs");
    assert_eq!(clean.active_count(), 0, "{:?}", clean.findings);
    // The fastpath legalised engine → stats (the clean fixture covers
    // it); the reverse direction must still fire.
    let rev = lint_sources(&[(
        "rust/src/stats/order_sampler.rs".to_string(),
        "use crate::engine::FastpathGather;\nfn f() {}\n".to_string(),
    )]);
    assert_eq!(rules_fired(&rev), ["L001"]);
    assert!(rev.findings[0].message.contains("crate::engine"));
    // The heterogeneous fastpath leans on stats → straggler and
    // engine → comm (the clean fixture covers the forward edges); the
    // reverse directions must still fire.
    for (rev_rel, rev_top, src) in [
        (
            "rust/src/straggler/models.rs",
            "straggler",
            "use crate::stats::ClassOrderSampler;\nfn f() {}\n",
        ),
        (
            "rust/src/comm/link.rs",
            "comm",
            "use crate::engine::EngineCore;\nfn f() {}\n",
        ),
    ] {
        let r = lint_sources(&[(rev_rel.to_string(), src.to_string())]);
        assert_eq!(rules_fired(&r), ["L001"], "{rev_top}");
        assert!(
            r.findings[0].message.contains(rev_top),
            "{:?}",
            r.findings
        );
    }
    // Intra-round parallelism legalised engine → exec and grad → exec
    // (Parallelism tokens, block helpers, scratch arena); the reverse
    // edges from true leaves stay illegal.
    for clean_rel in
        ["rust/src/engine/core.rs", "rust/src/grad/native.rs"]
    {
        let r = lint_sources(&[(
            clean_rel.to_string(),
            "use crate::exec::Parallelism;\nfn f() {}\n".to_string(),
        )]);
        assert_eq!(r.active_count(), 0, "{clean_rel}: {:?}", r.findings);
    }
    for leaf_rel in ["rust/src/linalg/ops.rs", "rust/src/rng/mod.rs"] {
        let r = lint_sources(&[(
            leaf_rel.to_string(),
            "use crate::exec::Parallelism;\nfn f() {}\n".to_string(),
        )]);
        assert_eq!(rules_fired(&r), ["L001"], "{leaf_rel}");
        assert!(r.findings[0].message.contains("crate::exec"));
    }
}

#[test]
fn s001_csv_drift_fires_and_registry_match_is_clean() {
    let bad = lint_at("rust/src/metrics/csv.rs", "s001_csv_bad.rs");
    let fired = rules_fired(&bad);
    assert!(fired.len() >= 2, "{:?}", bad.findings);
    assert!(fired.iter().all(|r| *r == "S001"));
    // The clean case is generated from the registry itself so this
    // test cannot drift when the schema is legitimately bumped.
    let (version, columns) = *CSV_SCHEMA_VERSIONS.last().unwrap();
    let clean_src = format!(
        "pub const CSV_COLUMNS: &str = \"{columns}\";\n\
         fn header() -> String {{\n\
         \x20   format!(\"# adasgd run series v{version}; columns: \
         {{CSV_COLUMNS}}\")\n}}\n"
    );
    let clean = lint_sources(&[(
        "rust/src/metrics/csv.rs".to_string(),
        clean_src,
    )]);
    assert_eq!(clean.active_count(), 0, "{:?}", clean.findings);
}

#[test]
fn s001_trace_kind_drift_fires_and_wired_kinds_are_clean() {
    let bad = lint_at("rust/src/trace/event.rs", "s001_event_bad.rs");
    let msgs: Vec<&str> =
        bad.findings.iter().map(|f| f.message.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("reuses tag 1")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("tag 0")), "{msgs:?}");
    assert!(
        msgs.iter().any(|m| m.contains("KIND_HALFWIRED referenced 2x")),
        "{msgs:?}"
    );
    let clean = lint_at("rust/src/trace/event.rs", "s001_event_clean.rs");
    assert_eq!(clean.active_count(), 0, "{:?}", clean.findings);
}

#[test]
fn lexer_torture_fixture_is_clean_everywhere() {
    // Violations spelled inside comments, nested block comments, raw
    // strings, cooked strings (with continuations), and char literals
    // must never fire — in the strictest module scope.
    for rel in ["rust/src/engine/fx.rs", "rust/src/exec/fx.rs"] {
        let r = lint_sources(&[(
            rel.to_string(),
            fixture("lexer_torture.rs"),
        )]);
        assert_eq!(r.findings.len(), 0, "{rel}: {:?}", r.findings);
    }
}

#[test]
fn whole_repo_lints_clean_with_visible_suppressions() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_root(root).expect("walk repo");
    assert!(
        report.files_scanned > 100,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
    let active: Vec<String> = report
        .active()
        .map(|f| format!("{}:{} {} {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(active.is_empty(), "repo must lint clean:\n{active:#?}");
    // The only sanctioned wall-clock reads are the pragma'd real_time
    // stats in the threaded cluster — visible, counted, D003.
    assert!(report.suppressed_count() >= 2);
    for f in &report.findings {
        if f.suppressed {
            assert_eq!(f.rule, "D003", "{}:{}", f.file, f.line);
            assert_eq!(f.file, "rust/src/exec/cluster.rs");
        }
    }
    // Fixtures are excluded from the walk: nothing scanned from there.
    assert!(report
        .findings
        .iter()
        .all(|f| !f.file.contains("lint_fixtures")));
}

#[test]
fn rule_table_matches_fixture_coverage() {
    // Every registered rule id appears in this suite's coverage; a new
    // rule without fixtures fails here first.
    let covered = [
        "D001", "D002", "D003", "D004", "D005", "D006", "L001", "S001",
    ];
    let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
    assert_eq!(ids, covered);
}

#[test]
fn json_report_round_trips_through_repo_parser() {
    let bad = lint_at("rust/src/exec/fx.rs", "d003_allowed.rs");
    let json = bad.render_json();
    let v = adasgd::config::json::Json::parse(&json).expect("valid json");
    assert_eq!(v.get("suppressed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("active").unwrap().as_usize().unwrap(), 0);
    let findings = v.get("findings").unwrap().as_arr().unwrap();
    assert_eq!(findings.len(), 1);
    assert_eq!(
        findings[0].get("rule").unwrap().as_str().unwrap(),
        "D003"
    );
}
