//! Integration: PJRT runtime + AOT artifacts vs the native backend.
//!
//! Requires `make artifacts` (the default Fig-2 shapes). These tests are
//! the numerical contract between the three layers: the Pallas kernel
//! (inside the HLO) must agree with the Rust linalg to f32 precision.

use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::grad::{GradBackend, NativeBackend};
use adasgd::master::{run_fastest_k, MasterConfig};
use adasgd::model::LinRegProblem;
use adasgd::policy::FixedK;
use adasgd::runtime::{Runtime, XlaApplyUpdate, XlaBackend, XlaLossEval};
use adasgd::straggler::ExponentialDelays;
use std::sync::Arc;

fn runtime() -> Arc<Runtime> {
    let dir = std::env::var("ADASGD_ARTIFACTS")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into());
    Runtime::open(&dir).expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    )
}

fn fig2_data() -> (SyntheticDataset, Shards) {
    let ds = SyntheticDataset::generate(SyntheticConfig::default(), 33);
    let shards = Shards::partition(&ds, 50);
    (ds, shards)
}

#[test]
fn manifest_lists_linreg_artifacts() {
    let rt = runtime();
    let names = rt.manifest().names();
    assert!(names.iter().any(|n| n == "linreg_grad_s40_d100"), "{names:?}");
    assert!(names.iter().any(|n| n == "linreg_loss_m2000_d100"));
    assert!(names.iter().any(|n| n == "apply_update_n50_d100"));
}

#[test]
fn xla_partial_grad_matches_native() {
    let rt = runtime();
    let (_ds, shards) = fig2_data();
    let mut xla = XlaBackend::new(&rt, &shards).expect("load xla backend");
    let mut native = NativeBackend::new(shards.clone());

    let w: Vec<f32> = (0..100).map(|i| (i as f32) * 0.7 - 30.0).collect();
    let mut gx = vec![0.0f32; 100];
    let mut gn = vec![0.0f32; 100];
    for shard in [0usize, 7, 49] {
        xla.partial_grad(shard, &w, &mut gx);
        native.partial_grad(shard, &w, &mut gn);
        for j in 0..100 {
            let rel = (gx[j] - gn[j]).abs() / gn[j].abs().max(1.0);
            assert!(
                rel < 1e-4,
                "shard {shard} j={j}: xla {} vs native {}",
                gx[j],
                gn[j]
            );
        }
    }
}

#[test]
fn xla_loss_matches_native() {
    let rt = runtime();
    let (ds, _) = fig2_data();
    let eval = XlaLossEval::new(&rt, &ds.x, &ds.y).expect("load loss");
    let w = vec![0.5f32; 100];
    let xla_loss = eval.loss(&w).expect("loss exec");
    let native_loss = adasgd::model::loss(&ds.x, &ds.y, &w);
    let rel = (xla_loss - native_loss).abs() / native_loss;
    assert!(rel < 1e-5, "xla {xla_loss} vs native {native_loss}");
}

#[test]
fn xla_apply_update_matches_host_update() {
    let rt = runtime();
    let apply = XlaApplyUpdate::new(&rt, 50, 100).expect("load apply");
    let mut w_xla: Vec<f32> = (0..100).map(|i| i as f32).collect();
    let w0 = w_xla.clone();
    // Stack: first k=3 rows populated, rest zero.
    let mut g = vec![0.0f32; 50 * 100];
    for r in 0..3 {
        for c in 0..100 {
            g[r * 100 + c] = (r + 1) as f32 * 0.01 * c as f32;
        }
    }
    let eta = 0.05f32;
    apply.apply(&mut w_xla, &g, eta / 3.0).expect("apply exec");
    for c in 0..100 {
        let sum: f32 = (0..3).map(|r| g[r * 100 + c]).sum();
        let want = w0[c] - eta / 3.0 * sum;
        assert!(
            (w_xla[c] - want).abs() < 1e-4 * want.abs().max(1.0),
            "c={c}: {} vs {}",
            w_xla[c],
            want
        );
    }
}

#[test]
fn full_training_loop_through_pjrt() {
    // The paper's Fig-2 workload, gradients through the Pallas artifact.
    let rt = runtime();
    let (ds, shards) = fig2_data();
    let problem = LinRegProblem::new(&ds);
    let mut backend = XlaBackend::new(&rt, &shards).expect("backend");
    let delays = ExponentialDelays::new(1.0);
    let mut policy = FixedK::new(10);
    let cfg = MasterConfig {
        eta: 5e-4,
        momentum: 0.0,
        max_iterations: 150,
        max_time: 0.0,
        seed: 9,
        record_stride: 50,
        intra_jobs: 1,
    };
    let run = run_fastest_k(
        &mut backend,
        &delays,
        &mut policy,
        &vec![0.0f32; 100],
        &cfg,
        &mut |w| problem.error(w),
    );
    let first = run.recorder.samples()[0].error;
    let last = run.recorder.last().unwrap().error;
    assert!(last < first * 0.1, "PJRT training failed: {first} -> {last}");
}

#[test]
fn xla_and_native_runs_agree_bitwise_on_delays() {
    // Same seed ⇒ identical straggler pattern ⇒ identical iteration times,
    // and near-identical trajectories (f32 kernel vs f32 linalg).
    let rt = runtime();
    let (ds, shards) = fig2_data();
    let problem = LinRegProblem::new(&ds);
    let delays = ExponentialDelays::new(1.0);
    let cfg = MasterConfig {
        eta: 5e-4,
        momentum: 0.0,
        max_iterations: 60,
        max_time: 0.0,
        seed: 12,
        record_stride: 20,
        intra_jobs: 1,
    };
    let mut native = NativeBackend::new(shards.clone());
    let mut p1 = FixedK::new(5);
    let rn = run_fastest_k(
        &mut native,
        &delays,
        &mut p1,
        &vec![0.0f32; 100],
        &cfg,
        &mut |w| problem.error(w),
    );
    let mut xla = XlaBackend::new(&rt, &shards).expect("backend");
    let mut p2 = FixedK::new(5);
    let rx = run_fastest_k(
        &mut xla,
        &delays,
        &mut p2,
        &vec![0.0f32; 100],
        &cfg,
        &mut |w| problem.error(w),
    );
    assert_eq!(rn.total_time, rx.total_time, "delay streams must match");
    // Trajectory parity: relative error of final iterates.
    for j in 0..100 {
        let rel = (rn.w[j] - rx.w[j]).abs() / rn.w[j].abs().max(1.0);
        assert!(rel < 1e-3, "j={j}: native {} xla {}", rn.w[j], rx.w[j]);
    }
}

#[test]
fn runtime_rejects_wrong_shapes() {
    let rt = runtime();
    let exe = rt.load("linreg_grad_s40_d100").expect("load");
    let bad = vec![0.0f32; 10];
    let err = match exe.run(&[
        adasgd::runtime::Arg::F32(&bad),
        adasgd::runtime::Arg::F32(&bad),
        adasgd::runtime::Arg::F32(&bad),
    ]) {
        Ok(_) => panic!("wrong shapes must be rejected"),
        Err(e) => e,
    };
    let msg = format!("{err}");
    assert!(msg.contains("signature mismatch"), "{msg}");
}

#[test]
fn runtime_unknown_artifact_is_helpful() {
    let rt = runtime();
    let err = match rt.load("nope") {
        Ok(_) => panic!("unknown artifact must fail"),
        Err(e) => e,
    };
    let msg = format!("{err}");
    assert!(msg.contains("not in manifest"), "{msg}");
    assert!(msg.contains("linreg_grad_s40_d100"), "should list known: {msg}");
}

#[test]
fn batched_all_grads_matches_per_shard() {
    let rt = runtime();
    let (_ds, shards) = fig2_data();
    let mut xla = XlaBackend::new(&rt, &shards).expect("backend");
    let w: Vec<f32> = (0..100).map(|i| (i as f32) * 0.3 - 10.0).collect();
    let mut all = vec![0.0f32; 50 * 100];
    assert!(
        xla.all_grads(&w, &mut all),
        "batched artifact should be available after `make artifacts`"
    );
    let mut single = vec![0.0f32; 100];
    for shard in [0usize, 13, 49] {
        xla.partial_grad(shard, &w, &mut single);
        for j in 0..100 {
            let a = all[shard * 100 + j];
            let rel = (a - single[j]).abs() / single[j].abs().max(1.0);
            assert!(rel < 1e-4, "shard {shard} j={j}: {a} vs {}", single[j]);
        }
    }
}
