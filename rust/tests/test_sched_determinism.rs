//! Scheduling invisibility of the work-stealing executor.
//!
//! The `exec::ThreadPool` deals jobs round-robin onto per-worker deques
//! and lets idle workers steal from their siblings' backs. That changes
//! *where* a job runs — never *what* it computes: every spec's RNG
//! streams derive from its own pinned seed and the executor reassembles
//! completions into spec order. These tests drive the pool with the
//! grid shape stealing exists for — one cell ~20x the cost of its
//! siblings — and assert `--jobs 1` and `--jobs N` stay byte-identical,
//! plus a regression test that a panic inside a *stolen* job still
//! propagates out of `map`.
//!
//! The same invisibility claim holds one level down: `--intra-jobs`
//! forks the work *inside* one round (responder partial gradients into
//! arena slots, column-blocked merge/apply) on the same shared pool.
//! The cross-product tests here drive a mixed-discipline grid
//! (sync, priced-comm, async, coded) through `--jobs J --intra-jobs I`
//! and assert every (J, I) yields byte-identical outputs and CSVs,
//! and that a panic inside `parallel_for` propagates without wedging
//! the pool for subsequent fork–joins.

use std::sync::{Arc, Barrier};

use adasgd::config::{
    CodingSchemeSpec, CodingSpec, CommSpec, CompressorSpec, DelaySpec,
    ExperimentConfig, PolicySpec, WorkloadSpec,
};
use adasgd::coordinator::ExperimentOutput;
use adasgd::exec::ThreadPool;
use adasgd::sweep::{write_sweep_csv, RunSpec, SweepExecutor};

fn skew_base() -> ExperimentConfig {
    ExperimentConfig {
        label: String::new(),
        n: 10,
        eta: 1e-3,
        max_iterations: 100,
        max_time: 0.0,
        seed: 7,
        record_stride: 20,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 5 },
        workload: WorkloadSpec::LinReg { m: 200, d: 10 },
        comm: Default::default(),
        coding: None,
        jobs: 0,
        intra_jobs: 1,
        trace: None,
        fastpath: false,
    }
}

/// A deliberately skewed grid: cell 0 runs 20x the iterations of its
/// nine siblings, so under round-robin dealing without stealing the
/// workers sharing its deque would tail-block behind it. Each cell gets
/// its own seed so outputs are distinguishable.
fn skewed_specs() -> Vec<RunSpec> {
    (0..10usize)
        .map(|i| {
            let mut cfg = skew_base();
            cfg.max_iterations = if i == 0 { 2_000 } else { 100 };
            cfg.seed = 100 + i as u64;
            cfg.label = format!(
                "skew/cell{i}/{}",
                if i == 0 { "heavy" } else { "light" }
            );
            RunSpec::from_config(i, cfg)
        })
        .collect()
}

fn assert_outputs_identical(a: &ExperimentOutput, b: &ExperimentOutput) {
    assert_eq!(a.recorder.label, b.recorder.label);
    assert_eq!(
        a.recorder.samples(),
        b.recorder.samples(),
        "{}: recorded series must be bitwise equal",
        a.recorder.label
    );
    assert_eq!(a.steps, b.steps, "{}", a.recorder.label);
    assert_eq!(
        a.total_time.to_bits(),
        b.total_time.to_bits(),
        "{}: clock must be bitwise equal",
        a.recorder.label
    );
    assert_eq!(a.k_changes, b.k_changes, "{}", a.recorder.label);
    assert_eq!(a.bytes_sent, b.bytes_sent, "{}", a.recorder.label);
    assert_eq!(a.bytes_down, b.bytes_down, "{}", a.recorder.label);
    assert_eq!(a.comm_time.to_bits(), b.comm_time.to_bits(), "{}", a.recorder.label);
    assert_eq!(a.down_time.to_bits(), b.down_time.to_bits(), "{}", a.recorder.label);
}

#[test]
fn skewed_grid_outputs_are_jobs_invariant() {
    let specs = skewed_specs();
    let seq = SweepExecutor::new(1).run(&specs).expect("sequential sweep");
    // jobs=4 forces steals (the heavy cell pins one worker); jobs=16
    // oversubscribes (more workers than cells) so most workers only
    // ever run stolen or dealt-singleton jobs.
    for jobs in [4usize, 16] {
        let par = SweepExecutor::new(jobs).run(&specs).expect("parallel sweep");
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_outputs_identical(a, b);
        }
    }
    // The skew is real: the heavy cell did ~20x the steps.
    assert_eq!(seq[0].steps, 2_000);
    assert!(seq[1..].iter().all(|o| o.steps == 100));
}

#[test]
fn skewed_grid_csvs_are_byte_identical() {
    let specs = skewed_specs();
    let dir = std::env::temp_dir().join("adasgd_sched_determinism_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let p1 = dir.join("jobs1.csv");
    let p3 = dir.join("jobs3.csv");
    let seq = SweepExecutor::new(1).run(&specs).expect("sequential sweep");
    let par = SweepExecutor::new(3).run(&specs).expect("parallel sweep");
    write_sweep_csv(&p1, &specs, &seq).unwrap();
    write_sweep_csv(&p3, &specs, &par).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b3 = std::fs::read(&p3).unwrap();
    assert!(!b1.is_empty());
    assert_eq!(b1, b3, "worker count must never reach the CSV bytes");
    std::fs::remove_dir_all(&dir).ok();
}

/// A priced, compressed channel: uplink qsgd + downlink top-k over
/// finite links with shared ingress, so comm RNG draws and byte
/// accounting are live in the rounds under test.
fn priced_comm() -> CommSpec {
    CommSpec {
        scheme: CompressorSpec::Qsgd { levels: 4 },
        downlink: CompressorSpec::TopK { frac: 0.25 },
        bandwidth: 2_000.0,
        latency: 0.05,
        down_bandwidth: 4_000.0,
        ingress_bw: 8_000.0,
        ..Default::default()
    }
}

/// One cell per gather discipline that routes through `EngineCore`:
/// plain sync fastest-k, sync over a priced channel at a d that spans
/// several intra blocks (so the column split is real), async over the
/// same priced channel, and coded (FRC) both free and priced. Only
/// `intra_jobs` varies between calls — it must never reach the bytes.
fn discipline_specs(intra_jobs: usize) -> Vec<RunSpec> {
    let cells: Vec<(&str, PolicySpec, Option<CodingSpec>, CommSpec, usize)> = vec![
        (
            "sync-dense",
            PolicySpec::Fixed { k: 5 },
            None,
            Default::default(),
            10,
        ),
        (
            "sync-priced-wide",
            PolicySpec::Fixed { k: 5 },
            None,
            priced_comm(),
            9_000,
        ),
        ("async-priced", PolicySpec::Async, None, priced_comm(), 10),
        (
            "coded-frc",
            PolicySpec::Fixed { k: 5 },
            Some(CodingSpec { scheme: CodingSchemeSpec::Frc, r: 2 }),
            Default::default(),
            10,
        ),
        (
            "coded-priced",
            PolicySpec::Fixed { k: 5 },
            Some(CodingSpec { scheme: CodingSchemeSpec::Frc, r: 2 }),
            priced_comm(),
            10,
        ),
    ];
    cells
        .into_iter()
        .enumerate()
        .map(|(i, (name, policy, coding, comm, d))| {
            let mut cfg = skew_base();
            cfg.label = format!("disc/{name}");
            cfg.max_iterations = 40;
            cfg.seed = 40 + i as u64;
            cfg.record_stride = 10;
            cfg.policy = policy;
            cfg.coding = coding;
            cfg.comm = comm;
            cfg.workload = WorkloadSpec::LinReg { m: 80, d };
            cfg.intra_jobs = intra_jobs;
            RunSpec::from_config(i, cfg)
        })
        .collect()
}

/// The tentpole acceptance test: `--jobs J --intra-jobs I` is
/// byte-identical across all (J, I) for every discipline. I = 3 and 4
/// exercise partial arenas (k = 5 slots over fewer workers), I = 16
/// oversubscribes the block count at d = 10 (blocks < threads).
#[test]
fn discipline_grid_is_jobs_and_intra_jobs_invariant() {
    let reference =
        SweepExecutor::new(1).run(&discipline_specs(1)).expect("reference");
    assert_eq!(reference.len(), 5);
    for jobs in [1usize, 3] {
        for intra in [1usize, 3, 4, 16] {
            if (jobs, intra) == (1, 1) {
                continue;
            }
            let out = SweepExecutor::new(jobs)
                .run(&discipline_specs(intra))
                .expect("parallel sweep");
            assert_eq!(reference.len(), out.len());
            for (a, b) in reference.iter().zip(&out) {
                assert_outputs_identical(a, b);
            }
        }
    }
}

/// ... and the CSVs those runs write are byte-for-byte the same file:
/// `intra_jobs` differs inside the specs, but it is pure wall-clock
/// configuration and must never appear in headers, meta, or samples.
#[test]
fn discipline_grid_csvs_are_intra_jobs_invariant() {
    let dir = std::env::temp_dir().join("adasgd_intra_determinism_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let p_serial = dir.join("j1i1.csv");
    let p_forked = dir.join("j3i16.csv");
    let serial_specs = discipline_specs(1);
    let forked_specs = discipline_specs(16);
    let serial =
        SweepExecutor::new(1).run(&serial_specs).expect("serial sweep");
    let forked =
        SweepExecutor::new(3).run(&forked_specs).expect("forked sweep");
    write_sweep_csv(&p_serial, &serial_specs, &serial).unwrap();
    write_sweep_csv(&p_forked, &forked_specs, &forked).unwrap();
    let a = std::fs::read(&p_serial).unwrap();
    let b = std::fs::read(&p_forked).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "(jobs, intra_jobs) must never reach the CSV bytes");
    std::fs::remove_dir_all(&dir).ok();
}

/// The threaded (real-OS-thread) cluster honours the same contract:
/// the master's merge/apply loops fork by `intra_jobs`, the result
/// does not move by a bit.
#[test]
fn threaded_cluster_is_intra_jobs_invariant() {
    use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
    use adasgd::exec::{ThreadedCluster, ThreadedConfig};
    use adasgd::model::LinRegProblem;
    use adasgd::policy::FixedK;

    let ds = SyntheticDataset::generate(
        SyntheticConfig { m: 160, d: 40, ..Default::default() },
        3,
    );
    let problem = LinRegProblem::new(&ds);
    let shards = Shards::partition(&ds, 8);
    let mut runs = Vec::new();
    for intra in [1usize, 4] {
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-6);
        let cfg = ThreadedConfig {
            eta: 1e-3,
            max_iterations: 60,
            time_scale: 1e-6,
            seed: 5,
            record_stride: 10,
            intra_jobs: intra,
        };
        let mut policy = FixedK::new(4);
        let run = cluster.run_fastest_k(
            &mut policy,
            &vec![0.0f32; 40],
            &cfg,
            &mut |w| problem.error(w),
        );
        runs.push(run);
    }
    let (a, b) = (&runs[0], &runs[1]);
    assert_eq!(a.recorder.samples(), b.recorder.samples());
    let wa: Vec<u32> = a.w.iter().map(|v| v.to_bits()).collect();
    let wb: Vec<u32> = b.w.iter().map(|v| v.to_bits()).collect();
    assert_eq!(wa, wb, "threaded model must be bitwise intra-invariant");
    assert_eq!(a.virtual_time.to_bits(), b.virtual_time.to_bits());
}

/// Adversarial-shape property sweep over the fork–join block helpers,
/// at the integration level: random lengths hugging the block
/// boundaries, random data including signed zeros and subnormal-scale
/// values, every worker budget — bitwise equal to the serial loop.
#[test]
fn block_reduction_is_bitwise_serial_for_adversarial_shapes() {
    use adasgd::exec::{zip_block_mut, Parallelism, INTRA_BLOCK};
    use adasgd::rng::{Pcg64, Rng};

    let mut rng = Pcg64::seed(41);
    let mut lens: Vec<usize> = vec![0, 1, 2];
    for b in 1..=3usize {
        let edge = b * INTRA_BLOCK;
        lens.extend([edge - 1, edge, edge + 1]);
    }
    for _ in 0..4 {
        lens.push((rng.next_u64() % (3 * INTRA_BLOCK as u64)) as usize);
    }
    for len in lens {
        let x: Vec<f32> = (0..len)
            .map(|i| {
                let r = rng.next_f64() as f32 - 0.5;
                match i % 5 {
                    0 => r * 1.0e8,
                    1 => -0.0,
                    2 => r * f32::MIN_POSITIVE,
                    _ => r,
                }
            })
            .collect();
        let y0: Vec<f32> =
            (0..len).map(|i| 1.0e7 - i as f32 * 0.625).collect();
        let mut y_ref = y0.clone();
        for (yv, xv) in y_ref.iter_mut().zip(&x) {
            *yv = *yv * 0.75 + *xv;
        }
        for jobs in [2usize, 4, 16] {
            let mut y = y0.clone();
            zip_block_mut(Parallelism::new(jobs), &mut y, &x, |_, yc, xc| {
                for (yv, xv) in yc.iter_mut().zip(xc) {
                    *yv = *yv * 0.75 + *xv;
                }
            });
            let rb: Vec<u32> = y_ref.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(rb, yb, "len={len} jobs={jobs}");
        }
    }
}

/// A panic inside a `parallel_for` body unwinds to the caller and the
/// pool keeps serving fork–joins afterwards — the poisoned round dies,
/// the process (and the rest of the sweep) does not wedge.
#[test]
fn panic_in_parallel_for_propagates_without_wedging_the_pool() {
    let pool = ThreadPool::new(3).expect("pool");
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || {
            pool.parallel_for(3, 64, |b| {
                if b == 17 {
                    panic!("block 17 exploded");
                }
            });
        },
    ));
    let msg = caught.expect_err("the body panic must unwind to the caller");
    let text = msg
        .downcast_ref::<&str>()
        .copied()
        .map(str::to_string)
        .or_else(|| msg.downcast_ref::<String>().cloned())
        .unwrap_or_default();
    assert!(text.contains("block 17 exploded"), "{text}");
    // The pool is not wedged: a fresh fork–join and a map both complete.
    let mut hits = vec![0u8; 32];
    {
        let slots = std::sync::Mutex::new(&mut hits);
        pool.parallel_for(3, 32, |b| {
            slots.lock().unwrap()[b] += 1;
        });
    }
    assert!(hits.iter().all(|&h| h == 1));
    let doubled = pool.map(8, |i| i * 2);
    assert_eq!(doubled, vec![0, 2, 4, 6, 8, 10, 12, 14]);
}

/// Panic propagation through the *stealing* path, deterministically.
///
/// Pool of 2, 4 jobs: round-robin dealing puts {0, 2} on worker 0's
/// deque and {1, 3} on worker 1's. Job 0 blocks on a barrier, so job 2
/// (behind it on the same deque) can only ever run by being stolen —
/// steals pop the back, so no interleaving lets one thread run both 0
/// and 2. The thief runs job 2, meets job 0 at the barrier (releasing
/// both), then job 2 panics; `map` must resurface that panic.
#[test]
#[should_panic(expected = "stolen job 2 exploded")]
fn panic_in_a_stolen_job_propagates_out_of_map() {
    let pool = ThreadPool::new(2).expect("two-worker pool");
    let barrier = Arc::new(Barrier::new(2));
    let b = Arc::clone(&barrier);
    let _ = pool.map(4, move |i| {
        match i {
            0 => {
                b.wait();
            }
            2 => {
                b.wait();
                panic!("stolen job 2 exploded");
            }
            _ => {}
        }
        i
    });
}
