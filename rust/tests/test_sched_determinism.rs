//! Scheduling invisibility of the work-stealing executor.
//!
//! The `exec::ThreadPool` deals jobs round-robin onto per-worker deques
//! and lets idle workers steal from their siblings' backs. That changes
//! *where* a job runs — never *what* it computes: every spec's RNG
//! streams derive from its own pinned seed and the executor reassembles
//! completions into spec order. These tests drive the pool with the
//! grid shape stealing exists for — one cell ~20x the cost of its
//! siblings — and assert `--jobs 1` and `--jobs N` stay byte-identical,
//! plus a regression test that a panic inside a *stolen* job still
//! propagates out of `map`.

use std::sync::{Arc, Barrier};

use adasgd::config::{DelaySpec, ExperimentConfig, PolicySpec, WorkloadSpec};
use adasgd::coordinator::ExperimentOutput;
use adasgd::exec::ThreadPool;
use adasgd::sweep::{write_sweep_csv, RunSpec, SweepExecutor};

fn skew_base() -> ExperimentConfig {
    ExperimentConfig {
        label: String::new(),
        n: 10,
        eta: 1e-3,
        max_iterations: 100,
        max_time: 0.0,
        seed: 7,
        record_stride: 20,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 5 },
        workload: WorkloadSpec::LinReg { m: 200, d: 10 },
        comm: Default::default(),
        coding: None,
        jobs: 0,
        trace: None,
        fastpath: false,
    }
}

/// A deliberately skewed grid: cell 0 runs 20x the iterations of its
/// nine siblings, so under round-robin dealing without stealing the
/// workers sharing its deque would tail-block behind it. Each cell gets
/// its own seed so outputs are distinguishable.
fn skewed_specs() -> Vec<RunSpec> {
    (0..10usize)
        .map(|i| {
            let mut cfg = skew_base();
            cfg.max_iterations = if i == 0 { 2_000 } else { 100 };
            cfg.seed = 100 + i as u64;
            cfg.label = format!(
                "skew/cell{i}/{}",
                if i == 0 { "heavy" } else { "light" }
            );
            RunSpec::from_config(i, cfg)
        })
        .collect()
}

fn assert_outputs_identical(a: &ExperimentOutput, b: &ExperimentOutput) {
    assert_eq!(a.recorder.label, b.recorder.label);
    assert_eq!(
        a.recorder.samples(),
        b.recorder.samples(),
        "{}: recorded series must be bitwise equal",
        a.recorder.label
    );
    assert_eq!(a.steps, b.steps, "{}", a.recorder.label);
    assert_eq!(
        a.total_time.to_bits(),
        b.total_time.to_bits(),
        "{}: clock must be bitwise equal",
        a.recorder.label
    );
    assert_eq!(a.k_changes, b.k_changes, "{}", a.recorder.label);
    assert_eq!(a.bytes_sent, b.bytes_sent, "{}", a.recorder.label);
    assert_eq!(a.bytes_down, b.bytes_down, "{}", a.recorder.label);
    assert_eq!(a.comm_time.to_bits(), b.comm_time.to_bits(), "{}", a.recorder.label);
    assert_eq!(a.down_time.to_bits(), b.down_time.to_bits(), "{}", a.recorder.label);
}

#[test]
fn skewed_grid_outputs_are_jobs_invariant() {
    let specs = skewed_specs();
    let seq = SweepExecutor::new(1).run(&specs).expect("sequential sweep");
    // jobs=4 forces steals (the heavy cell pins one worker); jobs=16
    // oversubscribes (more workers than cells) so most workers only
    // ever run stolen or dealt-singleton jobs.
    for jobs in [4usize, 16] {
        let par = SweepExecutor::new(jobs).run(&specs).expect("parallel sweep");
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_outputs_identical(a, b);
        }
    }
    // The skew is real: the heavy cell did ~20x the steps.
    assert_eq!(seq[0].steps, 2_000);
    assert!(seq[1..].iter().all(|o| o.steps == 100));
}

#[test]
fn skewed_grid_csvs_are_byte_identical() {
    let specs = skewed_specs();
    let dir = std::env::temp_dir().join("adasgd_sched_determinism_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let p1 = dir.join("jobs1.csv");
    let p3 = dir.join("jobs3.csv");
    let seq = SweepExecutor::new(1).run(&specs).expect("sequential sweep");
    let par = SweepExecutor::new(3).run(&specs).expect("parallel sweep");
    write_sweep_csv(&p1, &specs, &seq).unwrap();
    write_sweep_csv(&p3, &specs, &par).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b3 = std::fs::read(&p3).unwrap();
    assert!(!b1.is_empty());
    assert_eq!(b1, b3, "worker count must never reach the CSV bytes");
    std::fs::remove_dir_all(&dir).ok();
}

/// Panic propagation through the *stealing* path, deterministically.
///
/// Pool of 2, 4 jobs: round-robin dealing puts {0, 2} on worker 0's
/// deque and {1, 3} on worker 1's. Job 0 blocks on a barrier, so job 2
/// (behind it on the same deque) can only ever run by being stolen —
/// steals pop the back, so no interleaving lets one thread run both 0
/// and 2. The thief runs job 2, meets job 0 at the barrier (releasing
/// both), then job 2 panics; `map` must resurface that panic.
#[test]
#[should_panic(expected = "stolen job 2 exploded")]
fn panic_in_a_stolen_job_propagates_out_of_map() {
    let pool = ThreadPool::new(2).expect("two-worker pool");
    let barrier = Arc::new(Barrier::new(2));
    let b = Arc::clone(&barrier);
    let _ = pool.map(4, move |i| {
        match i {
            0 => {
                b.wait();
            }
            2 => {
                b.wait();
                panic!("stolen job 2 exploded");
            }
            _ => {}
        }
        i
    });
}
