//! The sweep layer's core contract, asserted end to end: `--jobs 1` and
//! `--jobs N` are **byte-identical** — same recorders, same aggregate
//! curves, same CSV bytes — across a scenario grid of {delay models ×
//! k-policies × coded/uncoded × priced/dense channels} (proptest-style
//! exhaustive enumeration of the axes, plus async riders).
//!
//! Why this must hold: every spec's RNG streams derive from its own
//! `cfg.seed`, pinned at grid-build time (`sweep::derive_seed` /
//! explicit per-spec seeds); specs share no mutable state; and the
//! executor reassembles completions into spec order. If any of those
//! three breaks, parallel completion order leaks into results and these
//! tests catch it.

use adasgd::config::{
    CodingSchemeSpec, CodingSpec, CommSpec, CompressorSpec, DelaySpec,
    ExperimentConfig, PolicySpec, WorkloadSpec,
};
use adasgd::coordinator::{run_repeated_jobs, ExperimentOutput};
use adasgd::policy::PflugParams;
use adasgd::sweep::{
    derive_seed, edit, sweep_meta, write_sweep_csv, RunSpec, SweepExecutor,
    SweepGrid,
};

fn tiny_base() -> ExperimentConfig {
    ExperimentConfig {
        label: String::new(),
        n: 10,
        eta: 1e-3,
        max_iterations: 120,
        max_time: 0.0,
        seed: 7,
        record_stride: 20,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 5 },
        workload: WorkloadSpec::LinReg { m: 200, d: 10 },
        comm: Default::default(),
        coding: None,
        jobs: 0,
        intra_jobs: 1,
        trace: None,
        fastpath: false,
    }
}

/// The scenario grid: 2 delay models × 2 policies × {uncoded, frc r=2}
/// × {dense free channel, priced top-k + finite ingress} = 16 specs,
/// plus 2 async riders (async × coding is rejected at validation, so
/// async joins as explicit specs rather than a policy-axis value).
fn scenario_specs() -> Vec<RunSpec> {
    let mut specs = SweepGrid::new(tiny_base())
        .axis(
            "delay",
            vec![
                (
                    "exp".to_string(),
                    edit(|c| c.delays = DelaySpec::Exponential { lambda: 1.0 }),
                ),
                (
                    "pareto".to_string(),
                    edit(|c| {
                        c.delays = DelaySpec::Pareto { xm: 0.5, alpha: 2.5 }
                    }),
                ),
            ],
        )
        .axis(
            "policy",
            vec![
                (
                    "k5".to_string(),
                    edit(|c| c.policy = PolicySpec::Fixed { k: 5 }),
                ),
                (
                    "adaptive".to_string(),
                    edit(|c| {
                        c.policy = PolicySpec::Adaptive(PflugParams {
                            k0: 2,
                            step: 2,
                            thresh: 5,
                            burnin: 20,
                            k_max: 10,
                        })
                    }),
                ),
            ],
        )
        .axis(
            "coding",
            vec![
                ("uncoded".to_string(), edit(|c| c.coding = None)),
                (
                    "frc2".to_string(),
                    edit(|c| {
                        c.coding = Some(CodingSpec {
                            scheme: CodingSchemeSpec::Frc,
                            r: 2,
                        })
                    }),
                ),
            ],
        )
        .axis(
            "channel",
            vec![
                (
                    "dense-free".to_string(),
                    edit(|c| c.comm = CommSpec::default()),
                ),
                (
                    "topk-priced".to_string(),
                    edit(|c| {
                        c.comm.scheme = CompressorSpec::TopK { frac: 0.3 };
                        c.comm.bandwidth = 500.0;
                        c.comm.latency = 0.01;
                        c.comm.ingress_bw = 2000.0;
                    }),
                ),
            ],
        )
        .build();
    for priced in [false, true] {
        let mut cfg = tiny_base();
        cfg.policy = PolicySpec::Async;
        cfg.label = format!(
            "async/{}",
            if priced { "topk-priced" } else { "dense-free" }
        );
        if priced {
            cfg.comm.scheme = CompressorSpec::TopK { frac: 0.3 };
            cfg.comm.bandwidth = 500.0;
            cfg.comm.latency = 0.01;
            cfg.comm.ingress_bw = 2000.0;
        }
        specs.push(RunSpec::from_config(specs.len(), cfg));
    }
    specs
}

fn assert_outputs_identical(a: &ExperimentOutput, b: &ExperimentOutput) {
    assert_eq!(a.recorder.label, b.recorder.label);
    assert_eq!(
        a.recorder.samples(),
        b.recorder.samples(),
        "{}: recorded series must be bitwise equal",
        a.recorder.label
    );
    assert_eq!(a.steps, b.steps, "{}", a.recorder.label);
    assert_eq!(
        a.total_time.to_bits(),
        b.total_time.to_bits(),
        "{}: clock must be bitwise equal",
        a.recorder.label
    );
    assert_eq!(a.k_changes, b.k_changes, "{}", a.recorder.label);
    assert_eq!(a.bytes_sent, b.bytes_sent, "{}", a.recorder.label);
    assert_eq!(a.bytes_down, b.bytes_down, "{}", a.recorder.label);
    assert_eq!(
        a.comm_time.to_bits(),
        b.comm_time.to_bits(),
        "{}",
        a.recorder.label
    );
    assert_eq!(
        a.down_time.to_bits(),
        b.down_time.to_bits(),
        "{}",
        a.recorder.label
    );
}

#[test]
fn jobs_1_and_jobs_4_outputs_are_bitwise_identical() {
    let specs = scenario_specs();
    assert_eq!(specs.len(), 18, "2 delay x 2 policy x 2 coding x 2 channel + 2 async");
    let seq = SweepExecutor::new(1).run(&specs).expect("sequential sweep");
    let par = SweepExecutor::new(4).run(&specs).expect("parallel sweep");
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_outputs_identical(a, b);
    }
    // Sanity that the grid actually exercised distinct scenarios: the
    // priced channels metered bytes and the labels are unique.
    let mut labels: Vec<&str> =
        specs.iter().map(|s| s.label.as_str()).collect();
    labels.sort_unstable();
    labels.dedup();
    assert_eq!(labels.len(), specs.len(), "labels must be unique");
    assert!(seq.iter().any(|o| o.comm_time > 0.0));
}

#[test]
fn jobs_1_and_jobs_4_csvs_are_byte_identical() {
    let specs = scenario_specs();
    let dir = std::env::temp_dir().join("adasgd_sweep_equivalence_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let p1 = dir.join("jobs1.csv");
    let p4 = dir.join("jobs4.csv");
    let seq = SweepExecutor::new(1).run(&specs).expect("sequential sweep");
    let par = SweepExecutor::new(4).run(&specs).expect("parallel sweep");
    write_sweep_csv(&p1, &specs, &seq).unwrap();
    write_sweep_csv(&p4, &specs, &par).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b4 = std::fs::read(&p4).unwrap();
    assert!(!b1.is_empty());
    assert_eq!(b1, b4, "jobs must never reach the CSV bytes");
    // The run-header meta lines carry the scenario axes.
    let text = String::from_utf8(b1).unwrap();
    assert!(
        text.contains("# sweep: 18 runs over delay x policy x coding x channel"),
        "{}",
        text.lines().take(3).collect::<Vec<_>>().join("\n")
    );
    assert!(text.contains(
        "# run exp/k5/frc2/topk-priced: delay=exp policy=k5 coding=frc2 \
         channel=topk-priced rng_seed=7"
    ));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn repeated_aggregate_is_jobs_invariant() {
    let mut base = tiny_base();
    base.label = "agg".into();
    base.max_time = 40.0;
    base.max_iterations = 10_000;
    let seq = run_repeated_jobs(&base, 100, 5, 16, 1).unwrap();
    let par = run_repeated_jobs(&base, 100, 5, 16, 4).unwrap();
    assert_eq!(seq, par, "aggregation must walk outputs in spec order");
    assert_eq!(seq.reps, 5);
    assert!(seq.final_mean().is_finite());
}

#[test]
fn derived_seeds_are_order_free_and_collision_free() {
    // The RNG-derivation rule: a spec's seed depends only on (base,
    // index) — evaluating in any order gives the same streams.
    let forward: Vec<u64> = (0..32).map(|i| derive_seed(11, i)).collect();
    let mut backward: Vec<u64> =
        (0..32).rev().map(|i| derive_seed(11, i)).collect();
    backward.reverse();
    assert_eq!(forward, backward);
    let mut dedup = forward.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), forward.len());
}

#[test]
fn grid_meta_is_deterministic_and_ordered() {
    let specs = scenario_specs();
    let m1 = sweep_meta(&specs);
    let m2 = sweep_meta(&scenario_specs());
    assert_eq!(m1, m2);
    assert_eq!(m1.len(), specs.len() + 1);
    // Spec order in the meta mirrors spec order in the grid.
    assert!(m1[1].starts_with("run exp/k5/uncoded/dense-free:"), "{}", m1[1]);
    assert!(m1[16].starts_with("run pareto/adaptive/frc2/topk-priced:"), "{}", m1[16]);
    assert!(m1[17].starts_with("run async/dense-free:"), "{}", m1[17]);
}
