//! Record→replay contract of the binary event-trace subsystem.
//!
//! A traced run must change nothing (tracing is purely observational:
//! same model, same clock, same recorder samples as the untraced run),
//! and a recorded trace must be able to re-drive the engine with
//! [`ReplayDelays`] standing in for live delay sampling — *bitwise*
//! equal trajectories across all four gather disciplines (sync
//! fastest-k, async staleness, coded, threaded cluster), on both the
//! dense-free channel and priced/compressed channels. The trace also
//! round-trips through the binary codec and mines into a
//! [`TraceDelays`] straggler scenario that reproduces the recording.

use adasgd::async_sgd::{run_async_comm_traced, AsyncConfig};
use adasgd::coding::{run_coded_comm_traced, CyclicRepetition};
use adasgd::comm::{
    Broadcast, CommChannel, DownlinkMode, IngressModel, LinkModel,
    QuantizeQsgd, TopK,
};
use adasgd::config::{
    DelaySpec, ExperimentConfig, PolicySpec, WorkloadSpec,
};
use adasgd::coordinator::{replay_experiment, run_experiment};
use adasgd::data::{Shards, SyntheticConfig, SyntheticDataset};
use adasgd::grad::NativeBackend;
use adasgd::master::{run_fastest_k_comm_traced, MasterConfig};
use adasgd::metrics::Sample;
use adasgd::model::LinRegProblem;
use adasgd::policy::FixedK;
use adasgd::straggler::{ExponentialDelays, TraceDelays};
use adasgd::trace::{Discipline, ReplayDelays, Trace};

const N: usize = 10;

fn setup(seed: u64) -> (NativeBackend, LinRegProblem) {
    let ds = SyntheticDataset::generate(
        SyntheticConfig { m: 200, d: 10, ..Default::default() },
        seed,
    );
    let problem = LinRegProblem::new(&ds);
    (NativeBackend::new(Shards::partition(&ds, N)), problem)
}

fn delays() -> ExponentialDelays {
    ExponentialDelays::new(1.0)
}

type ChannelFactory = Box<dyn Fn() -> CommChannel>;

/// Dense-free plus a priced/compressed configuration — channels are
/// stateful, so every run builds a fresh one from its factory.
fn channels() -> Vec<(&'static str, ChannelFactory)> {
    vec![
        ("dense-free", Box::new(|| CommChannel::dense(N))),
        (
            "qsgd-delta-ingress",
            Box::new(|| {
                CommChannel::new(
                    Box::new(QuantizeQsgd::new(4)),
                    LinkModel::uniform(N, 800.0, 0.01),
                    true,
                )
                .with_broadcast(Broadcast::new(
                    Box::new(TopK::new(0.5)),
                    LinkModel::uniform(N, 400.0, 0.0),
                    DownlinkMode::Delta,
                ))
                .with_ingress(IngressModel::new(500.0))
            }),
        ),
    ]
}

/// The strict form of "the same trajectory": every f64 compared on its
/// bit pattern, not through float `==`.
fn assert_samples_bitwise(tag: &str, a: &[Sample], b: &[Sample]) {
    assert_eq!(a.len(), b.len(), "{tag}: sample count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let same = x.iteration == y.iteration
            && x.time.to_bits() == y.time.to_bits()
            && x.k == y.k
            && x.error.to_bits() == y.error.to_bits()
            && x.bytes == y.bytes
            && x.comm_time.to_bits() == y.comm_time.to_bits()
            && x.bytes_down == y.bytes_down
            && x.down_time.to_bits() == y.down_time.to_bits();
        assert!(same, "{tag}: sample {i} differs: {x:?} vs {y:?}");
    }
}

// ---------------------------------------------------------------------
// Sync fastest-k.
// ---------------------------------------------------------------------

#[test]
fn sync_record_replay_is_bitwise_on_dense_and_priced_channels() {
    for (name, make_channel) in channels() {
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 120,
            seed: 5,
            record_stride: 20,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run = |model: &dyn adasgd::straggler::DelayModel,
                   trace: bool| {
            let (mut backend, problem) = setup(5);
            let mut policy = FixedK::new(4);
            let mut channel = make_channel();
            run_fastest_k_comm_traced(
                &mut backend,
                model,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
                trace,
            )
        };
        let recorded = run(&delays(), true);
        let trace =
            recorded.trace.as_ref().expect("traced run carries a trace");
        assert_eq!(trace.discipline, Discipline::Sync);
        assert_eq!(trace.n_workers as usize, N);
        assert!(!trace.is_empty(), "{name}: trace recorded no events");

        // Tracing off preserves the run byte for byte.
        let untraced = run(&delays(), false);
        assert!(untraced.trace.is_none());
        assert_eq!(untraced.w, recorded.w, "{name}: tracing changed w");
        assert_eq!(
            untraced.total_time.to_bits(),
            recorded.total_time.to_bits(),
            "{name}: tracing changed the clock"
        );
        assert_samples_bitwise(
            &format!("sync/{name}/traced-vs-untraced"),
            untraced.recorder.samples(),
            recorded.recorder.samples(),
        );

        // Replay from the recorded raw draws alone.
        let replay = ReplayDelays::from_trace(trace).expect("replayable");
        let replayed = run(&replay, false);
        assert_eq!(replayed.w, recorded.w, "{name}: replayed model");
        assert_eq!(
            replayed.total_time.to_bits(),
            recorded.total_time.to_bits(),
            "{name}: replayed clock"
        );
        assert_eq!(
            replayed.k_changes, recorded.k_changes,
            "{name}: replayed k switches"
        );
        assert_samples_bitwise(
            &format!("sync/{name}/replay"),
            recorded.recorder.samples(),
            replayed.recorder.samples(),
        );
    }
}

// ---------------------------------------------------------------------
// Async staleness.
// ---------------------------------------------------------------------

#[test]
fn async_record_replay_is_bitwise_on_dense_and_priced_channels() {
    for (name, make_channel) in channels() {
        let cfg = AsyncConfig {
            eta: 0.0005,
            max_updates: 400,
            seed: 11,
            record_stride: 100,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run = |model: &dyn adasgd::straggler::DelayModel,
                   trace: bool| {
            let (mut backend, problem) = setup(11);
            let mut channel = make_channel();
            run_async_comm_traced(
                &mut backend,
                model,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
                trace,
            )
        };
        let recorded = run(&delays(), true);
        let trace =
            recorded.trace.as_ref().expect("traced run carries a trace");
        assert_eq!(trace.discipline, Discipline::Async);

        let untraced = run(&delays(), false);
        assert_eq!(untraced.w, recorded.w, "{name}: tracing changed w");
        assert_eq!(
            untraced.total_time.to_bits(),
            recorded.total_time.to_bits()
        );

        let replay = ReplayDelays::from_trace(trace).expect("replayable");
        let replayed = run(&replay, false);
        assert_eq!(replayed.w, recorded.w, "{name}: replayed model");
        assert_eq!(
            replayed.total_time.to_bits(),
            recorded.total_time.to_bits(),
            "{name}: replayed clock"
        );
        assert_eq!(
            replayed.mean_staleness.to_bits(),
            recorded.mean_staleness.to_bits(),
            "{name}: replayed staleness"
        );
        assert_samples_bitwise(
            &format!("async/{name}/replay"),
            recorded.recorder.samples(),
            replayed.recorder.samples(),
        );
    }
}

// ---------------------------------------------------------------------
// Coded gather.
// ---------------------------------------------------------------------

#[test]
fn coded_record_replay_is_bitwise_on_dense_and_priced_channels() {
    for (name, make_channel) in channels() {
        let cfg = MasterConfig {
            eta: 0.002,
            max_iterations: 80,
            seed: 2,
            record_stride: 20,
            ..Default::default()
        };
        let w0 = vec![0.0f32; 10];
        let run = |model: &dyn adasgd::straggler::DelayModel,
                   trace: bool| {
            let (mut backend, problem) = setup(2);
            let scheme = CyclicRepetition::new(N, 3).expect("cyclic(10,3)");
            let mut policy = FixedK::new(8);
            let mut channel = make_channel();
            run_coded_comm_traced(
                &mut backend,
                model,
                &scheme,
                &mut policy,
                &mut channel,
                &w0,
                &cfg,
                &mut |w| problem.error(w),
                trace,
            )
        };
        let recorded = run(&delays(), true);
        let trace =
            recorded.trace.as_ref().expect("traced run carries a trace");
        assert_eq!(trace.discipline, Discipline::Coded);

        let untraced = run(&delays(), false);
        assert_eq!(untraced.w, recorded.w, "{name}: tracing changed w");
        assert_eq!(
            untraced.total_time.to_bits(),
            recorded.total_time.to_bits()
        );

        let replay = ReplayDelays::from_trace(trace).expect("replayable");
        let replayed = run(&replay, false);
        assert_eq!(replayed.w, recorded.w, "{name}: replayed model");
        assert_eq!(
            replayed.total_time.to_bits(),
            recorded.total_time.to_bits(),
            "{name}: replayed clock"
        );
        assert_samples_bitwise(
            &format!("coded/{name}/replay"),
            recorded.recorder.samples(),
            replayed.recorder.samples(),
        );
    }
}

// ---------------------------------------------------------------------
// Threaded cluster (round-based and async modes).
// ---------------------------------------------------------------------

#[test]
fn threaded_record_replay_is_bitwise() {
    use adasgd::exec::{ThreadedCluster, ThreadedConfig};
    let seed = 3u64;
    let ds = SyntheticDataset::generate(
        SyntheticConfig { m: 200, d: 10, ..Default::default() },
        seed,
    );
    let problem = LinRegProblem::new(&ds);
    let cfg = ThreadedConfig {
        eta: 0.002,
        max_iterations: 100,
        time_scale: 1e-6,
        seed,
        record_stride: 20,
        intra_jobs: 1,
    };
    let run = |model: &dyn adasgd::straggler::DelayModel, trace: bool| {
        let shards = Shards::partition(&ds, N);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-6);
        let mut policy = FixedK::new(4);
        let mut channel = CommChannel::dense(N);
        cluster.run_with_comm_traced(
            model,
            &mut channel,
            &mut policy,
            &vec![0.0f32; 10],
            &cfg,
            &mut |w| problem.error(w),
            trace,
        )
    };
    let recorded = run(&delays(), true);
    let trace = recorded.trace.as_ref().expect("traced run carries a trace");
    assert_eq!(trace.discipline, Discipline::Threaded);

    let untraced = run(&delays(), false);
    assert_eq!(untraced.w, recorded.w, "tracing changed w");
    assert_eq!(
        untraced.virtual_time.to_bits(),
        recorded.virtual_time.to_bits()
    );

    let replay = ReplayDelays::from_trace(trace).expect("replayable");
    let replayed = run(&replay, false);
    assert_eq!(replayed.w, recorded.w, "replayed model");
    assert_eq!(
        replayed.virtual_time.to_bits(),
        recorded.virtual_time.to_bits(),
        "replayed clock"
    );
    assert_samples_bitwise(
        "threaded/replay",
        recorded.recorder.samples(),
        replayed.recorder.samples(),
    );
}

#[test]
fn threaded_async_record_replay_is_bitwise() {
    use adasgd::exec::ThreadedCluster;
    let seed = 13u64;
    let ds = SyntheticDataset::generate(
        SyntheticConfig { m: 200, d: 10, ..Default::default() },
        seed,
    );
    let problem = LinRegProblem::new(&ds);
    let cfg = AsyncConfig {
        eta: 0.0005,
        max_updates: 300,
        seed,
        record_stride: 100,
        ..Default::default()
    };
    let run = |model: &dyn adasgd::straggler::DelayModel, trace: bool| {
        let shards = Shards::partition(&ds, N);
        let mut cluster = ThreadedCluster::spawn(&shards, 1e-6);
        let mut channel = CommChannel::dense(N);
        cluster.run_async_comm_traced(
            model,
            &mut channel,
            &vec![0.0f32; 10],
            &cfg,
            &mut |w| problem.error(w),
            trace,
        )
    };
    let recorded = run(&delays(), true);
    let trace = recorded.trace.as_ref().expect("traced run carries a trace");
    assert_eq!(trace.discipline, Discipline::ThreadedAsync);

    let replay = ReplayDelays::from_trace(trace).expect("replayable");
    let replayed = run(&replay, false);
    assert_eq!(replayed.w, recorded.w, "replayed model");
    assert_eq!(
        replayed.virtual_time.to_bits(),
        recorded.virtual_time.to_bits(),
        "replayed clock"
    );
    assert_samples_bitwise(
        "threaded-async/replay",
        recorded.recorder.samples(),
        replayed.recorder.samples(),
    );
}

// ---------------------------------------------------------------------
// Codec round trip + trace mining.
// ---------------------------------------------------------------------

/// A short recorded sync trace fixture on the dense channel.
fn recorded_sync() -> (adasgd::master::FastestKRun, Trace) {
    let cfg = MasterConfig {
        eta: 0.002,
        max_iterations: 60,
        seed: 7,
        record_stride: 20,
        ..Default::default()
    };
    let (mut backend, problem) = setup(7);
    let mut policy = FixedK::new(4);
    let mut channel = CommChannel::dense(N);
    let run = run_fastest_k_comm_traced(
        &mut backend,
        &delays(),
        &mut policy,
        &mut channel,
        &vec![0.0f32; 10],
        &cfg,
        &mut |w| problem.error(w),
        true,
    );
    let trace = run.trace.clone().expect("traced run carries a trace");
    (run, trace)
}

#[test]
fn trace_survives_the_binary_codec_and_the_filesystem() {
    let (_, trace) = recorded_sync();
    let decoded =
        Trace::from_bytes(&trace.to_bytes()).expect("codec round trip");
    assert_eq!(decoded, trace, "in-memory codec round trip");

    let dir = std::env::temp_dir()
        .join(format!("adasgd-trace-test-{}", std::process::id()));
    let path = dir.join("roundtrip.trace");
    trace.save(&path).expect("save");
    let loaded = Trace::load(&path).expect("load");
    assert_eq!(loaded, trace, "filesystem round trip");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mined_event_trace_reproduces_the_recorded_run() {
    // Every sync round draws all n workers, so the mined table covers
    // the full run and replaying it through the *straggler* layer (not
    // ReplayDelays) reproduces the same trajectory bitwise.
    let (recorded, trace) = recorded_sync();
    let mined = TraceDelays::from_event_trace(&trace).expect("minable");
    assert_eq!(mined.len() as u64, recorded.iterations);
    assert_eq!(mined.workers(), N);

    let cfg = MasterConfig {
        eta: 0.002,
        max_iterations: 60,
        seed: 7,
        record_stride: 20,
        ..Default::default()
    };
    let (mut backend, problem) = setup(7);
    let mut policy = FixedK::new(4);
    let mut channel = CommChannel::dense(N);
    let replayed = run_fastest_k_comm_traced(
        &mut backend,
        &mined,
        &mut policy,
        &mut channel,
        &vec![0.0f32; 10],
        &cfg,
        &mut |w| problem.error(w),
        false,
    );
    assert_eq!(replayed.w, recorded.w, "mined-replay model");
    assert_eq!(
        replayed.total_time.to_bits(),
        recorded.total_time.to_bits(),
        "mined-replay clock"
    );
    assert_samples_bitwise(
        "mined-replay",
        recorded.recorder.samples(),
        replayed.recorder.samples(),
    );
}

// ---------------------------------------------------------------------
// Coordinator end-to-end: per-spec trace file + replay_experiment.
// ---------------------------------------------------------------------

#[test]
fn run_experiment_writes_a_trace_file_that_replay_experiment_reproduces() {
    let dir = std::env::temp_dir()
        .join(format!("adasgd-trace-e2e-{}", std::process::id()));
    let cfg = ExperimentConfig {
        label: "trace e2e/cell#1".into(),
        n: N,
        eta: 0.002,
        max_iterations: 80,
        max_time: 0.0,
        seed: 4,
        record_stride: 20,
        delays: DelaySpec::Exponential { lambda: 1.0 },
        policy: PolicySpec::Fixed { k: 4 },
        workload: WorkloadSpec::LinReg { m: 200, d: 10 },
        comm: Default::default(),
        coding: None,
        jobs: 0,
        intra_jobs: 1,
        trace: Some(dir.display().to_string()),
        fastpath: false,
    };
    let recorded = run_experiment(&cfg).expect("traced run");
    let in_memory =
        recorded.trace.as_ref().expect("output keeps the trace");

    // The file is named from the sanitized label.
    let path = dir.join(format!(
        "{}.trace",
        adasgd::trace::sanitize_label(&cfg.label)
    ));
    assert!(path.exists(), "expected trace file at {}", path.display());
    let loaded = Trace::load(&path).expect("load recorded trace");
    assert_eq!(&loaded, in_memory, "saved trace round-trips");

    // Replay re-drives the coordinator path from the file alone; the
    // replayed run must match the recording bitwise (and record no
    // trace of its own).
    let replayed = replay_experiment(&cfg, &loaded).expect("replay");
    assert!(replayed.trace.is_none());
    assert_eq!(
        replayed.total_time.to_bits(),
        recorded.total_time.to_bits(),
        "replayed clock"
    );
    assert_eq!(replayed.steps, recorded.steps);
    assert_eq!(replayed.late_responses, recorded.late_responses);
    assert_samples_bitwise(
        "coordinator-replay",
        recorded.recorder.samples(),
        replayed.recorder.samples(),
    );
    let _ = std::fs::remove_dir_all(&dir);
}
