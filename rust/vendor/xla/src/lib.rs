//! Offline **API stub** for the `xla` / `xla_extension` PJRT bindings.
//!
//! The build environment for this repository has no network access and no
//! prebuilt `xla_extension`, so this crate mirrors the small API surface
//! `adasgd::runtime` consumes — just enough for `--features pjrt` code to
//! type-check. Every entry point that would touch PJRT returns
//! [`Error::Unavailable`] at runtime.
//!
//! To actually execute artifacts, replace this crate with the real
//! bindings, e.g. in the workspace `Cargo.toml`:
//!
//! ```toml
//! [patch.'crates-io']          # or edit the path dependency directly
//! xla = { git = "https://github.com/LaurentMazare/xla-rs" }
//! ```

use std::fmt;
use std::path::Path;

/// XLA/PJRT failure (stub: always [`Error::Unavailable`]).
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub backend cannot execute anything.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "PJRT unavailable ({what}): built against the offline xla \
                 API stub; install real xla_extension bindings to execute \
                 artifacts"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file (stub: always fails).
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

/// Host- or device-side tensor value (stub).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a host slice (stub value).
    pub fn vec1<T: Copy>(_data: &[T]) -> Self {
        Self(())
    }

    /// Reshape (stub: always fails).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Number of elements.
    pub fn element_count(&self) -> usize {
        0
    }

    /// Copy the payload into a host slice (stub: always fails).
    pub fn copy_raw_to<T: Copy>(&self, _out: &mut [T]) -> Result<()> {
        unavailable("Literal::copy_raw_to")
    }

    /// Destructure a tuple literal (stub: always fails).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Download to a host literal (stub: always fails).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with host literals (stub: always fails).
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    /// Execute with device buffers (stub: always fails).
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle (stub).
#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Create the CPU client (stub: always fails, so no stubbed executable
    /// can ever be reached through a successfully-constructed runtime).
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation (stub: always fails).
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    /// Upload a host buffer to the device (stub: always fails).
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1.0f32]);
        assert!(lit.reshape(&[1]).is_err());
        assert_eq!(lit.element_count(), 0);
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
